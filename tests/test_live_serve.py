"""Live serving under simulated time: open-loop arrival schedules,
the LiveServe record/replay round trip across engines, the golden
serve + co-located traces, the multi-driver recording guard, and the
workload-reset regressions (stale progress arrays across runs)."""
import json
import pathlib

import numpy as np
import pytest

from repro.core.cluster import ClusterSpec, StepCost
from repro.live import CostLedger, LiveTraceError, LiveTraceMismatch
from repro.sim import (ChipRingTraining, LiveProgram, LiveServe,
                       ModeledServe, Simulation, Topology,
                       UnsupportedByEngine, burst_arrivals,
                       live_colocated_sim, live_serve_sim,
                       poisson_arrivals, serve_latency)

from engine_harness import assert_reports_equal, engines_for, run_engine

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
SERVE_TRACE = GOLDEN_DIR / "live_serve_trace.json"
COLOCATED_TRACE = GOLDEN_DIR / "live_colocated_trace.json"


class DummyStack:
    """Cheap non-JAX serve stack for engine-harness round trips (the
    real-BatchServer path is exercised by the golden trace and the
    end-to-end record test below)."""

    def setup(self):
        pass

    def close(self):
        pass

    def prefill(self, wave, batch):
        return sum(i * i for i in range(400 + 50 * wave))

    def decode(self, wave, d):
        return sum(i * i for i in range(150 + 10 * d))


# ---------------------------------------------------------------------------
# arrival schedules
# ---------------------------------------------------------------------------


def test_poisson_arrivals_deterministic_and_monotone():
    a = poisson_arrivals(50, 1_000_000, seed=7)
    b = poisson_arrivals(50, 1_000_000, seed=7)
    assert (a == b).all()
    assert a.dtype == np.int64 and len(a) == 50
    assert (np.diff(a) >= 1).all() and a[0] >= 1
    c = poisson_arrivals(50, 1_000_000, seed=8)
    assert not (a == c).all()
    off = poisson_arrivals(3, 1_000, seed=0, start_ns=500)
    assert (off > 500).all()


def test_burst_arrivals_shape():
    a = burst_arrivals(7, 3, gap_ns=1_000_000, spread_ns=10)
    assert len(a) == 7
    assert list(a[:3]) == [1_000_000, 1_000_010, 1_000_020]
    assert a[3] == 2_000_000
    with pytest.raises(ValueError):
        burst_arrivals(0, 3, gap_ns=1_000)
    with pytest.raises(ValueError):
        poisson_arrivals(5, 0)


def test_live_serve_validates_schedule_and_mode():
    with pytest.raises(ValueError, match="ServeStack"):
        LiveServe(ledger=CostLedger.record(), arrivals=[1, 2])
    led = CostLedger.record()
    with pytest.raises(ValueError, match="non-decreasing"):
        LiveServe(ledger=led, stack=DummyStack(), arrivals=[5, 3])
    with pytest.raises(ValueError, match=">= 1"):
        LiveServe(ledger=led, stack=DummyStack(), arrivals=[0, 3])
    with pytest.raises(ValueError, match="non-empty"):
        LiveServe(ledger=led, stack=DummyStack(), arrivals=[])


# ---------------------------------------------------------------------------
# record/replay round trip across engines
# ---------------------------------------------------------------------------


def _round_trip_serve(n_hosts: int):
    """Record once in-process (cheap stack), then replay under every
    applicable engine and demand the full CORE_FIELDS bar — including
    the live section's latency percentiles — plus equality with the
    record run's timings."""
    arrivals = [int(v) for v in poisson_arrivals(10, 150_000, seed=3)]
    led = CostLedger.record(calibration=2.0)

    def make(ledger, stack=None):
        wl = LiveServe(ledger=ledger, stack=stack, arrivals=arrivals,
                       max_batch=3, decode_steps=2)
        if n_hosts == 1:
            return Simulation(Topology.single_host(n_cpus=2), wl)
        return Simulation(Topology.full_mesh(n_hosts, wl.link,
                                             n_cpus=2), wl,
                          placement=wl.default_placement())

    rec = make(led, DummyStack()).run(engine="async")
    assert rec.status == "ok"
    sec = rec.live["live_serve"]["tasks"]["serve.live"]
    assert sec["requests"] == 10
    assert sec["waves"] <= 10 and sec["max_wave_batch"] >= 1
    lat = sec["latency_ns"]
    assert lat["p50"] <= lat["p95"] <= lat["p99"] <= lat["max"]
    trace = led.to_dict()
    engines = engines_for(n_hosts)
    reports = {eng: run_engine(
        lambda: make(CostLedger.replay(trace)), eng)
        for eng in engines}
    base = engines[0]
    for eng in engines[1:]:
        assert_reports_equal(reports[base], reports[eng],
                             label=f"serve round-trip {n_hosts}h")
    # replayed vtimes and latency percentiles are the recorded ones
    assert reports[base].vtime_ns == rec.vtime_ns
    assert reports[base].tasks == rec.tasks
    assert reports[base].progress == rec.progress
    assert serve_latency(reports[base]) == serve_latency(rec)
    return reports


def test_serve_round_trip_single_host():
    _round_trip_serve(1)                   # single/barrier/async/dist:1


def test_serve_round_trip_multi_host():
    _round_trip_serve(2)                   # barrier/async/dist:1/dist:2


def test_serve_burst_queue_depth_exceeds_batch():
    # a burst larger than max_batch must show up as queue depth: the
    # server sees more pending arrivals than one wave can carry
    arrivals = [int(v) for v in burst_arrivals(6, 6, gap_ns=50_000_000)]
    led = CostLedger.record()
    wl = LiveServe(ledger=led, stack=DummyStack(), arrivals=arrivals,
                   max_batch=2, decode_steps=1)
    rep = Simulation(Topology.single_host(n_cpus=2), wl).run()
    sec = rep.live["live_serve"]["tasks"]["serve.live"]
    assert sec["requests"] == 6
    assert sec["max_wave_batch"] == 2
    assert sec["queue_depth"]["max"] > 2


def test_serve_unsupported_by_vectorized():
    wl = LiveServe(ledger=CostLedger.record(), stack=DummyStack(),
                   arrivals=[1_000])
    sim = Simulation(Topology.single_host(n_cpus=2), wl)
    with pytest.raises(UnsupportedByEngine):
        sim.run(engine="vectorized")


# ---------------------------------------------------------------------------
# multi-driver recording guard
# ---------------------------------------------------------------------------


def test_record_rejects_overlapping_spans():
    led = CostLedger.record()

    def nested():
        led.charge("b", "inner", lambda: None)

    with pytest.raises(LiveTraceError, match="concurrent record"):
        led.charge("a", "outer", nested)
    # the guard must clear on error: a later sequential charge works
    _, cost = led.charge("a", "retry", lambda: None)
    assert cost >= 1


def test_multi_driver_record_single_trace():
    # two live workloads, one ledger: both drivers' costs land in one
    # trace under disjoint task keys, and one replay drives both
    arrivals = [int(v) for v in poisson_arrivals(4, 200_000, seed=2)]
    led = CostLedger.record()

    def make(ledger, stack=None):
        fns = {"aux": (lambda step: sum(range(100)))} \
            if ledger.mode == "record" else {"aux": _aux}
        return Simulation(
            Topology.single_host(n_cpus=2),
            [LiveServe(ledger=ledger, stack=stack, arrivals=arrivals,
                       max_batch=2, decode_steps=1),
             LiveProgram(fns, 3, ledger=ledger, name="auxwl")])

    rec = make(led, DummyStack()).run()
    assert rec.status == "ok"
    assert set(led.tasks) == {"serve.live", "aux"}
    rep = make(CostLedger.replay(led.to_dict())).run()
    assert rep.vtime_ns == rec.vtime_ns
    assert rep.tasks == rec.tasks


def _aux(step):
    return None


# ---------------------------------------------------------------------------
# workload reset: stale progress arrays across runs (regression)
# ---------------------------------------------------------------------------


def _run_twice(make_sim_from_wl, wl):
    r1 = make_sim_from_wl(wl).run()
    r2 = make_sim_from_wl(wl).run()
    assert r1.status == r2.status == "ok"
    assert r1.progress == r2.progress, (
        "stale progress leaked into the second run")
    assert r1.vtime_ns == r2.vtime_ns
    assert r1.tasks == r2.tasks
    return r1, r2


def test_modeled_serve_instance_reusable():
    wl = ModeledServe(n_clients=3, n_requests=4, service_ns=100_000)
    _run_twice(lambda w: Simulation(Topology.single_host(n_cpus=2), w),
               wl)


def test_chip_ring_instance_reusable():
    wl = ChipRingTraining(ClusterSpec(n_pods=1, chips_per_pod=4),
                          StepCost(compute_ns=100_000,
                                   ici_bytes=10_000), 3)
    _run_twice(lambda w: Simulation(Topology.single_host(n_cpus=2), w),
               wl)


def test_live_replay_instance_reusable():
    # a replay workload reused across two runs must rewind its ledger
    # cursors: identical reports, including the live section
    led = CostLedger.record()
    sim = Simulation(Topology.single_host(n_cpus=2),
                     LiveProgram({"a": _aux}, 3, ledger=led))
    rec = sim.run()
    wl = LiveProgram({"a": _aux}, 3,
                     ledger=CostLedger.replay(led.to_dict()))
    r1, r2 = _run_twice(
        lambda w: Simulation(Topology.single_host(n_cpus=2), w), wl)
    assert r1.vtime_ns == rec.vtime_ns
    assert r1.live == r2.live


def test_live_serve_replay_instance_reusable():
    arrivals = [int(v) for v in poisson_arrivals(5, 150_000, seed=4)]
    led = CostLedger.record()
    Simulation(Topology.single_host(n_cpus=2),
               LiveServe(ledger=led, stack=DummyStack(),
                         arrivals=arrivals, max_batch=2,
                         decode_steps=1)).run()
    wl = LiveServe(ledger=CostLedger.replay(led.to_dict()),
                   arrivals=arrivals, max_batch=2, decode_steps=1)
    r1, r2 = _run_twice(
        lambda w: Simulation(Topology.single_host(n_cpus=2), w), wl)
    assert serve_latency(r1) == serve_latency(r2)


def test_record_rerun_guard_names_the_problem():
    # re-running a record workload would append a second copy of every
    # cost to the same trace — the reset must refuse, loudly
    led = CostLedger.record()
    wl = LiveServe(ledger=led, stack=DummyStack(), arrivals=[1_000],
                   max_batch=1, decode_steps=1)
    Simulation(Topology.single_host(n_cpus=2), wl).run()
    with pytest.raises(ValueError, match="one record run per ledger"):
        Simulation(Topology.single_host(n_cpus=2), wl).run()


# ---------------------------------------------------------------------------
# golden traces: serve + co-located live train/serve
# ---------------------------------------------------------------------------


def _replay_serve():
    return live_serve_sim(CostLedger.replay(SERVE_TRACE))


def _replay_colocated():
    return live_colocated_sim(CostLedger.replay(COLOCATED_TRACE))


def test_golden_serve_percentiles_and_meta():
    rep = _replay_serve().run(engine="async")
    assert rep.status == "ok"
    sec = rep.live["live_serve"]
    assert sec["mode"] == "replay"
    meta = CostLedger.replay(SERVE_TRACE).meta["serve"]
    task = sec["tasks"]["serve.live"]
    assert task["requests"] == len(meta["arrivals"])
    lat = task["latency_ns"]
    assert 0 < lat["p50"] <= lat["p95"] <= lat["p99"] <= lat["max"]
    assert task["queue_depth"]["samples"] == task["waves"]


def test_golden_serve_bit_identical_across_engines(engine_harness):
    reports = engine_harness(_replay_serve, label="live serve replay")
    for rep in reports.values():
        assert serve_latency(rep)["p99"] > 0


def test_golden_serve_trace_mismatch_fails_fast():
    sim = live_serve_sim(CostLedger.replay(SERVE_TRACE),
                         decode_steps=32)
    with pytest.raises(LiveTraceMismatch, match="'serve.live'"):
        sim.run(engine="async")


def test_golden_colocated_bit_identical_across_engines(engine_harness):
    reports = engine_harness(_replay_colocated,
                             label="live colocated replay")
    for rep in reports.values():
        # both drivers replayed from the one multi-driver trace, on a
        # shared cell that actually charged co-activity
        assert rep.live["live_train"]["tasks"]["live.trainer"][
            "final_step"] > 0
        assert serve_latency(rep)["p99"] > 0
        host0 = rep.cells["0"]["cells"]["colo"]
        assert host0["assigned"] == 2
        assert host0["live_calls"] > 0


def test_golden_colocated_arrivals_pinned_in_meta():
    # replays must never re-derive the schedule from an RNG stream:
    # the concrete integer arrivals are pinned in the trace meta
    meta = CostLedger.replay(COLOCATED_TRACE).meta["colocated"]
    arr = meta["serve"]["arrivals"]
    assert isinstance(arr, list) and len(arr) == meta["serve"][
        "n_requests"]
    assert all(isinstance(v, int) for v in arr)
    probe = CostLedger.replay(COLOCATED_TRACE).meta["serve_probe"]
    assert probe["mean_gap_ns"] == meta["serve"]["mean_gap_ns"]


def test_fail_probe_meta_pinned(tmp_path, monkeypatch):
    # satellite 3: the recovery recorder's fudge factor is a named
    # constant, and every freshly derived fail-at vtime carries its
    # audit trail (probe span -> margin -> vtime) in the trace meta
    import repro.sim.live as live_mod

    class DummyTrainer:
        def __init__(self, **kw):
            pass

        def setup(self):
            pass

        def step(self, step):
            return sum(range(500))

        def save(self, step):
            pass

        def restore(self):
            return 0

        def remesh(self):
            pass

        def close(self):
            pass

    monkeypatch.setattr(live_mod, "TrainerStack", DummyTrainer)
    out = tmp_path / "recovery_trace.json"
    report, ledger = live_mod.record_live_recovery(
        out, n_steps=4, checkpoint_every=2)
    assert report.status == "ok"
    probe = ledger.meta["fail_probe"]
    assert probe["margin_steps"] == live_mod.FAIL_PROBE_MARGIN_STEPS \
        == 0.5
    assert probe["steps_to_failure"] == 2 + 0.5
    assert probe["probe_span_ns"] >= 1
    assert probe["fail_at_vtime"] \
        == ledger.meta["recovery"]["fail_at_vtime"]


def test_serve_sim_rejects_unknown_override():
    with pytest.raises(ValueError, match="unknown serve parameters"):
        live_serve_sim(CostLedger.replay(SERVE_TRACE), bogus=1)
    with pytest.raises(ValueError, match="unknown colocated"):
        live_colocated_sim(CostLedger.replay(COLOCATED_TRACE), bogus={})


def test_serve_sim_requires_schedule_in_record_mode():
    with pytest.raises(ValueError, match="arrival"):
        live_serve_sim(CostLedger.record(), stack=DummyStack())


# ---------------------------------------------------------------------------
# end-to-end: the real BatchServer records and replays in-process
# ---------------------------------------------------------------------------


def test_real_batch_server_records_and_replays(tmp_path):
    """The full serve record run: real jitted BatchServer prefill +
    decode waves measured under engine='async' (one device suffices),
    then replayed bit-exactly in the same process."""
    from repro.sim import record_live_serve

    out = tmp_path / "serve_trace.json"
    report, ledger = record_live_serve(
        out, n_requests=4, max_batch=2, decode_steps=2)
    assert report.status == "ok"
    assert ledger.meta["serve_probe"]["probe_span_ns"] > 0
    assert len(ledger.meta["serve"]["arrivals"]) == 4
    data = json.loads(out.read_text())
    assert set(data["tasks"]) == {"serve.live"}
    rep = live_serve_sim(CostLedger.replay(out)).run(engine="async")
    assert rep.status == "ok"
    assert rep.vtime_ns == report.vtime_ns
    assert serve_latency(rep) == serve_latency(report)
