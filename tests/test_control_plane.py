"""Traffic-driven control plane (``repro.sim.control``).

Unit tests for the pure pieces — placement policies, the threshold
autoscaler's integer decision rule, the diurnal arrival schedule —
plus the engine-matrix test: a small autoscaled fleet with
late-joining pool hosts must produce bit-identical reports *and*
bit-identical ``SimReport.control`` sections (decisions, boots,
drains, probe counts, latency percentiles) on every engine.
"""
import pytest

from engine_harness import assert_engines_agree
from repro.sim import (AutoscaledServe, PLACEMENT_POLICIES, Scenario,
                       Simulation, ThresholdAutoscaler, Topology,
                       best_fit, diurnal_arrivals, first_fit,
                       worst_fit)

_LINK = Topology(1).default_host_link


# ---------------------------------------------------------------------------
# placement policies
# ---------------------------------------------------------------------------


def test_first_fit_prefers_lowest_idle_id():
    busy = [0, 500, 0, 0]
    assert first_fit([1, 2, 3], busy, now=100, service_ns=50,
                     cap_ns=400) == 2
    # all busy: least backlog wins, id breaks ties
    busy = [0, 900, 700, 700]
    assert first_fit([1, 2, 3], busy, now=100, service_ns=50,
                     cap_ns=400) == 2


def test_best_fit_packs_deepest_that_fits():
    busy = [0, 300, 150, 0]
    # backlogs at now=100: k1=200, k2=50, k3=0; service 100, cap 300
    # fits: k1 (200+100<=300), k2, k3 -> deepest backlog = k1
    assert best_fit([1, 2, 3], busy, now=100, service_ns=100,
                    cap_ns=300) == 1
    # nothing fits -> least backlog
    assert best_fit([1, 2], [0, 900, 800], now=100, service_ns=100,
                    cap_ns=100) == 2


def test_worst_fit_spreads_to_least_backlog():
    busy = [0, 300, 150, 150]
    assert worst_fit([1, 2, 3], busy, now=100, service_ns=100,
                     cap_ns=300) == 2  # id tie-break at equal backlog


def test_policy_registry_is_the_public_surface():
    assert PLACEMENT_POLICIES == {"first_fit": first_fit,
                                  "best_fit": best_fit,
                                  "worst_fit": worst_fit}


# ---------------------------------------------------------------------------
# threshold autoscaler
# ---------------------------------------------------------------------------


def test_autoscaler_target_thresholds_and_clamps():
    a = ThresholdAutoscaler(up_x1000=750, down_x1000=300, factor=2)
    assert a.target(800, 4, 2, 16) == 8
    assert a.target(800, 10, 2, 16) == 16      # clamped at max
    assert a.target(200, 8, 2, 16) == 4
    assert a.target(200, 3, 2, 16) == 2        # floor-div, clamped at min
    assert a.target(500, 8, 2, 16) == 8        # dead band holds


def test_autoscaler_validation():
    with pytest.raises(ValueError, match="down < up"):
        ThresholdAutoscaler(up_x1000=300, down_x1000=300)
    with pytest.raises(ValueError, match="factor"):
        ThresholdAutoscaler(factor=1)
    with pytest.raises(ValueError, match="patience"):
        ThresholdAutoscaler(patience=0)


# ---------------------------------------------------------------------------
# diurnal arrivals
# ---------------------------------------------------------------------------


def test_diurnal_arrivals_shape():
    def draw(seed):
        return list(diurnal_arrivals(500, base_gap_ns=1_000_000,
                                     peak_gap_ns=50_000,
                                     period_ns=100_000_000, seed=seed))

    arr = draw(7)
    assert len(arr) == 500
    assert all(b > a for a, b in zip(arr, arr[1:]))  # strictly increasing
    assert arr == draw(7)           # deterministic in the seed
    assert arr != draw(8)
    # the diurnal swing is real: gaps near the peak (half a period in)
    # are much shorter than gaps at the trough
    mid = min(range(len(arr)),
              key=lambda i: abs(arr[i] - 50_000_000))
    trough_gap = arr[1] - arr[0]
    peak_gap = arr[mid + 1] - arr[mid]
    assert peak_gap < trough_gap


# ---------------------------------------------------------------------------
# the fleet, cross-engine
# ---------------------------------------------------------------------------


def _fleet():
    n_pool, founding = 8, 4
    topo = Topology(n_hosts=n_pool + 1, n_cpus=2)
    topo.capacity_pool(range(founding + 1, n_pool + 1), 20_000_000,
                       stagger_ns=500_000)
    ready = [0] * founding + [20_000_000 + i * 500_000
                              for i in range(n_pool - founding)]
    wl = AutoscaledServe(
        arrivals=diurnal_arrivals(700, base_gap_ns=1_000_000,
                                  peak_gap_ns=60_000,
                                  period_ns=100_000_000, seed=5),
        n_pool=n_pool, ready_ns=ready, service_ns=400_000,
        min_active=founding, decide_every=8, probe_every=4,
        autoscaler=ThresholdAutoscaler(patience=2),
        placement="worst_fit")
    return Simulation(topo, wl, Scenario("autoscale smoke"),
                      placement=wl.default_placement())


def test_autoscaled_fleet_engine_matrix():
    reports = assert_engines_agree(_fleet, label="autoscale")
    ref = reports[sorted(reports)[0]]
    for eng, rep in reports.items():
        assert rep.control == ref.control, (
            f"control section diverged on {eng}")
    sec = ref.control["autoserve"]
    assert ref.status == "ok"
    assert sec["served"] == 700
    moves = [(d["from"], d["to"]) for d in sec["decisions"]
             if d["from"] != d["to"]]
    assert any(b > a for a, b in moves), "no scale-up observed"
    assert any(b < a for a, b in moves), "no scale-down observed"
    assert sec["peak_active"] > 4
    assert sec["final_active"] >= 4
    assert sec["probes"]["sent"] == sec["probes"]["acks"] > 0
    assert 0 < sec["latency_ns"]["p50"] <= sec["latency_ns"]["p99"] \
        <= sec["latency_ns"]["max"]
    # membership timeline carries the four late pool joins
    joins = [e for e in ref.control["membership"] if e["event"] == "join"]
    assert [e["host"] for e in joins] == [5, 6, 7, 8]


def test_autoscaled_serve_validation():
    arr = [1_000 * i for i in range(1, 20)]
    with pytest.raises(ValueError, match="placement"):
        AutoscaledServe(arrivals=arr, n_pool=4, placement="zany_fit")
    with pytest.raises(ValueError, match="min_active"):
        AutoscaledServe(arrivals=arr, n_pool=4, min_active=3,
                        ready_ns=[0, 0, 5_000, 5_000])


def test_control_report_absent_without_control_workload():
    from repro.sim import RackRing
    wl = RackRing(n_racks=1, hosts_per_rack=2, n_iters=4,
                  compute_ns=5_000)
    topo = Topology.full_mesh(2, link=_LINK, n_cpus=2)
    r = Simulation(topo, wl, Scenario("plain"),
                   placement=wl.default_placement()).run(engine="async")
    assert r.control == {}
