"""End-to-end behaviour tests for the paper's system."""
import sys

import numpy as np
import pytest

sys.path.insert(0, "/root/repo")


def test_table2_workloads_accuracy():
    """The headline reproduction: LiveStack predicts the physical
    testbed's runtime within the paper's accuracy band (>= ~70%) on
    every workload category, at reduced sizes.

    In-container bounds are looser than the paper's: the physical
    baselines share the host with everything else, and host load only
    ever *inflates* them (the live prediction is stable).  kvstore is
    the most load-sensitive (three GIL-sharing threads), so its bound
    guards against gross model regressions, not against a busy host."""
    from repro.core import workloads as wl

    kw = {"arith": dict(iters=60), "oltp": dict(n_req=120),
          "kvstore": dict(n_ops=100), "shuffle": dict(rounds=2)}
    thresholds = {"arith": 0.55, "oltp": 0.55,
                  "kvstore": 0.3, "shuffle": 0.55}
    for name, spec in wl.WORKLOADS.items():
        thr = thresholds[name]
        best = 0.0
        for _ in range(3):          # retries: physical runs are noisy
            phys = spec["physical"](**kw[name])
            live = spec["livestack"](**kw[name])
            best = max(best, wl.accuracy(live.sim_s, phys.sim_s))
            if best >= thr:
                break
        assert best >= thr, (name, best, phys.sim_s, live.sim_s)


def test_des_baseline_is_much_slower():
    """The gem5-comparison claim: the fine-grained DES baseline is
    orders of magnitude slower than LiveStack on the same workload."""
    from repro.core import workloads as wl

    live = wl.arith_livestack(iters=60)
    des = wl.arith_des(iters=60, grain_ns=20)
    assert des.wall_s > 5 * live.wall_s, (des.wall_s, live.wall_s)


def test_cluster_sim_matches_analytic():
    """512-chip training sim lands within 2x of the closed-form step
    time (the sim adds queuing the closed form ignores)."""
    from benchmarks import cluster_bench

    r = cluster_bench.simulate("qwen3_4b", n_steps=3, straggler=False)
    assert 0.3 <= r["ratio"] <= 2.0, r
    assert r["done_steps_min"] == 3


def test_cluster_sim_straggler_slows_cluster():
    from benchmarks import cluster_bench

    base = cluster_bench.simulate("qwen3_4b", n_steps=3, straggler=False)
    slow = cluster_bench.simulate("qwen3_4b", n_steps=3, straggler=True)
    # bounded-skew coupling: one 2x-slow chip must slow the whole step
    assert slow["sim_step_ms"] >= base["sim_step_ms"]


def test_scheduler_scales_with_vectorized_engine():
    from benchmarks import sched_scale

    ref = sched_scale.bench_reference(2048, 32, steps=10)
    vec = sched_scale.bench_vectorized(2048, 32, steps=10)
    assert vec["dispatch_per_s"] > ref["dispatch_per_s"]
