"""Hypothesis property tests on the system's invariants."""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (Compute, Scheduler, Scope, State, US, VTask)
from repro.core.engine_jax import (VecState, eligibility, hub_visibility,
                                   hub_visibility_ref, run_vectorized,
                                   scope_minima)


# ---------------------------------------------------------------------------
# Scheduler invariants
# ---------------------------------------------------------------------------


@st.composite
def compute_cluster(draw):
    n_tasks = draw(st.integers(2, 12))
    n_scopes = draw(st.integers(1, 4))
    tasks = []
    for i in range(n_tasks):
        steps = draw(st.integers(1, 15))
        dur = draw(st.integers(1, 200)) * US
        memberships = draw(st.sets(st.integers(0, n_scopes - 1),
                                   min_size=1, max_size=n_scopes))
        tasks.append((steps, dur, sorted(memberships)))
    skews = [draw(st.integers(1, 100)) * US for _ in range(n_scopes)]
    return tasks, skews


@given(compute_cluster())
@settings(max_examples=60, deadline=None)
def test_bounded_skew_never_violated_at_dispatch(cluster):
    """INVARIANT (paper dispatch rule): whenever a vtask executes a
    quantum, its vtime is within skew of every scope's runnable min."""
    tasks_spec, skews = cluster
    scopes = [Scope(f"s{i}", sk) for i, sk in enumerate(skews)]
    sched = Scheduler(n_cpus=3)
    violations = []

    def body(steps, dur):
        for _ in range(steps):
            yield Compute(dur)

    tasks = []
    for i, (steps, dur, members) in enumerate(tasks_spec):
        t = VTask(f"t{i}", body(steps, dur), kind="modeled")
        for m in members:
            t.join(scopes[m])
        tasks.append(sched.spawn(t))

    orig = sched._dispatch

    def checked(t):
        for s in t.scopes:
            sv = s.vtime
            if sv >= 0 and t.vtime > sv + s.skew_bound_ns:
                violations.append((t.name, t.vtime, s.name, sv))
        orig(t)

    sched._dispatch = checked
    sched.run(max_rounds=100_000)
    assert not violations
    assert all(t.state == State.DONE for t in tasks)


@given(compute_cluster())
@settings(max_examples=30, deadline=None)
def test_scheduler_deterministic(cluster):
    tasks_spec, skews = cluster

    def build():
        scopes = [Scope(f"s{i}", sk) for i, sk in enumerate(skews)]
        sched = Scheduler(n_cpus=2)

        def body(steps, dur):
            for _ in range(steps):
                yield Compute(dur)

        out = []
        for i, (steps, dur, members) in enumerate(tasks_spec):
            t = VTask(f"t{i}", body(steps, dur), kind="modeled")
            for m in members:
                t.join(scopes[m])
            out.append(sched.spawn(t))
        sched.run(max_rounds=100_000)
        return [(t.name, t.vtime) for t in out]

    assert build() == build()


@given(compute_cluster())
@settings(max_examples=30, deadline=None)
def test_vtime_conservation(cluster):
    """Compute-only vtasks end at exactly steps x duration (no vtime is
    lost or invented by scheduling)."""
    tasks_spec, skews = cluster
    scopes = [Scope(f"s{i}", sk) for i, sk in enumerate(skews)]
    sched = Scheduler(n_cpus=4)

    def body(steps, dur):
        for _ in range(steps):
            yield Compute(dur)

    ts = []
    for i, (steps, dur, members) in enumerate(tasks_spec):
        t = VTask(f"t{i}", body(steps, dur), kind="modeled")
        for m in members:
            t.join(scopes[m])
        ts.append((sched.spawn(t), steps * dur))
    sched.run(max_rounds=100_000)
    for t, expect in ts:
        assert t.vtime == expect


# ---------------------------------------------------------------------------
# Vectorized engine == reference semantics (compute-only workloads)
# ---------------------------------------------------------------------------


@given(compute_cluster())
@settings(max_examples=20, deadline=None)
def test_vectorized_engine_matches_reference_final_vtimes(cluster):
    """Same cluster, both engines: identical final vtimes (both implement
    bounded-skew rounds; with per-task fixed durations the trajectories
    coincide when n_cpus >= n_tasks)."""
    tasks_spec, skews = cluster
    n = len(tasks_spec)
    s = len(skews)

    # reference
    scopes = [Scope(f"s{i}", sk) for i, sk in enumerate(skews)]
    sched = Scheduler(n_cpus=n)

    def body(steps, dur):
        for _ in range(steps):
            yield Compute(dur)

    ref_tasks = []
    for i, (steps, dur, members) in enumerate(tasks_spec):
        t = VTask(f"t{i}", body(steps, dur), kind="modeled")
        for m in members:
            t.join(scopes[m])
        ref_tasks.append(sched.spawn(t))
    sched.run(max_rounds=200_000)

    # vectorized
    membership = np.zeros((n, s), bool)
    for i, (_, _, members) in enumerate(tasks_spec):
        membership[i, members] = True
    st_ = VecState.create(
        n, s,
        durations=[d for _, d, _ in tasks_spec],
        steps=[stp for stp, _, _ in tasks_spec],
        membership=membership,
        skews=skews)
    st_, _ = run_vectorized(st_, max_rounds=200_000)
    vec_vtimes = np.asarray(st_.vtime)
    for i, t in enumerate(ref_tasks):
        assert int(vec_vtimes[i]) == t.vtime, (i, tasks_spec[i])


# ---------------------------------------------------------------------------
# Eligibility math
# ---------------------------------------------------------------------------


@given(st.integers(2, 40), st.integers(1, 8), st.integers(0, 2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_global_min_always_eligible(n, s, seed):
    rng = np.random.default_rng(seed)
    vtime = rng.integers(0, 100_000, n).astype(np.int32)
    runnable = rng.random(n) < 0.8
    if not runnable.any():
        runnable[0] = True
    membership = rng.random((n, s)) < 0.4
    membership[:, 0] |= ~membership.any(axis=1)   # everyone in >=1 scope
    skew = rng.integers(1, 1000, s).astype(np.int32)
    import jax.numpy as jnp

    elig = eligibility(jnp.asarray(vtime), jnp.asarray(runnable),
                       jnp.asarray(membership), jnp.asarray(skew))
    elig = np.asarray(elig)
    r_idx = np.where(runnable)[0]
    gmin = r_idx[np.argmin(vtime[r_idx])]
    assert elig[gmin], "globally minimal runnable vtask must be eligible"


# ---------------------------------------------------------------------------
# Hub FIFO visibility (max-plus scan) == sequential oracle
# ---------------------------------------------------------------------------


@given(st.integers(1, 200), st.integers(1, 8), st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_hub_visibility_matches_oracle(m, n_links, seed):
    rng = np.random.default_rng(seed)
    link = np.sort(rng.integers(0, n_links, m)).astype(np.int32)
    send = np.zeros(m, np.int64)
    for l in range(n_links):
        idx = np.where(link == l)[0]
        send[idx] = np.sort(rng.integers(0, 1_000_000, len(idx)))
    size = rng.integers(1, 100_000, m).astype(np.int32)
    bw = rng.uniform(1e9, 100e9, n_links)
    lat = rng.integers(0, 100_000, n_links).astype(np.int32)
    import jax.numpy as jnp

    out = hub_visibility(jnp.asarray(send, jnp.int32), jnp.asarray(size),
                         jnp.asarray(link), jnp.asarray(bw, jnp.float32),
                         jnp.asarray(lat))
    ref = hub_visibility_ref(send, size, link, bw, lat)
    np.testing.assert_allclose(np.asarray(out, np.int64), ref, atol=16)


# ---------------------------------------------------------------------------
# Checkpoint roundtrip property
# ---------------------------------------------------------------------------


@given(shapes=st.lists(st.tuples(st.integers(1, 5), st.integers(1, 5)),
                       min_size=1, max_size=5),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_checkpoint_roundtrip_any_tree(shapes, seed):
    import tempfile

    import jax.numpy as jnp

    from repro.checkpoint import restore, save

    tmp = tempfile.mkdtemp(prefix="ckpt_prop_")
    rng = np.random.default_rng(seed)
    tree = {f"leaf{i}": jnp.asarray(rng.standard_normal(s), jnp.float32)
            for i, s in enumerate(shapes)}
    save(tmp, tree, step=1)
    got, step, _ = restore(tmp, tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(tree[k]))
