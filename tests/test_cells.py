"""Live memory-hierarchy management (paper §3.3)."""
import pytest

from repro.core import CellManager, Compute, LiveCall, Scheduler, Scope, \
    State, US, VTask
from repro.core.cells import _hash01


def test_spatial_interference_bandwidth():
    cm = CellManager()
    cm.create("a", ways=6, bw_share=0.5, bw_demand=0.6, mem_frac=0.5,
              working_set_frac=0.4)
    cm.create("b", ways=6, bw_share=0.5, bw_demand=0.6, mem_frac=0.5,
              working_set_frac=0.4)
    t = VTask("t", None, kind="live")
    cm.assign(t, "a")
    alone = cm.slowdown(t, [])
    contended = cm.slowdown(t, ["b"])
    assert contended > alone            # co-location slows the live host
    assert alone >= 1.0


def test_cache_overflow_penalty():
    cm = CellManager()
    cm.create("small", ways=2, working_set_frac=0.8, bw_demand=0.1)
    cm.create("big", ways=10, working_set_frac=0.8, bw_demand=0.1)
    ts = VTask("s", None, kind="live")
    tb = VTask("b", None, kind="live")
    cm.assign(ts, "small")
    cm.assign(tb, "big")
    assert cm.slowdown(ts, []) > cm.slowdown(tb, [])


def test_temporal_residue_reconditioning():
    cm = CellManager(n_warm_slots=1, recondition_ns=10_000)
    cm.create("a")
    cm.create("b")
    ta, tb = VTask("a", None, kind="live"), VTask("b", None, kind="live")
    cm.assign(ta, "a")
    cm.assign(tb, "b")
    c1 = cm.switch_cost(ta)        # cold
    assert c1 > 0
    assert cm.switch_cost(ta) == 0  # warm now
    c2 = cm.switch_cost(tb)        # evicts a
    assert c2 > 0
    c3 = cm.switch_cost(ta)        # a was evicted -> recondition again
    assert c3 > 0
    assert cm.stats["switches"] == 3


def test_residue_is_deterministic():
    assert _hash01(3, 7) == _hash01(3, 7)
    assert -1.0 <= _hash01(123, 456) < 1.0


def test_interference_folded_into_vtime():
    """Imperfect isolation is not hidden — it lands in simulated time."""
    cm = CellManager(recondition_ns=0)
    cm.create("noisy", ways=2, bw_share=0.3, bw_demand=0.9, mem_frac=1.0,
              working_set_frac=0.9)
    cm.create("victim", ways=2, bw_share=0.3, bw_demand=0.9, mem_frac=1.0,
              working_set_frac=0.9)
    sched = Scheduler(n_cpus=2, cells=cm)

    def live_body():
        for _ in range(3):
            yield LiveCall(lambda: sum(range(100)), cost_ns=100 * US)

    v = VTask("victim", live_body(), kind="live")
    n = VTask("noisy", live_body(), kind="live")
    cm.assign(v, "victim")
    cm.assign(n, "noisy")
    sched.spawn(v)
    sched.spawn(n)
    sched.run()
    # with a co-active noisy neighbor, vtime > pure cost
    assert v.vtime > 3 * 100 * US
    assert cm.stats["interference_events"] > 0


def test_isolated_cell_runs_at_cost():
    cm = CellManager(recondition_ns=0)
    cm.create("iso", ways=12, bw_share=1.0, bw_demand=0.2, mem_frac=0.3,
              working_set_frac=0.3)
    sched = Scheduler(n_cpus=1, cells=cm)

    def live_body():
        yield LiveCall(lambda: 1, cost_ns=100 * US)

    t = VTask("t", live_body(), kind="live")
    cm.assign(t, "iso")
    sched.spawn(t)
    sched.run()
    assert t.vtime == 100 * US
