"""Live memory-hierarchy management (paper §3.3)."""
import pytest

from repro.core import CellManager, Compute, LiveCall, Scheduler, Scope, \
    State, US, VTask
from repro.core.cells import _hash01
from repro.sim import (Interference, RackRing, Scenario, Simulation,
                       Topology)


def test_spatial_interference_bandwidth():
    cm = CellManager()
    cm.create("a", ways=6, bw_share=0.5, bw_demand=0.6, mem_frac=0.5,
              working_set_frac=0.4)
    cm.create("b", ways=6, bw_share=0.5, bw_demand=0.6, mem_frac=0.5,
              working_set_frac=0.4)
    t = VTask("t", None, kind="live")
    cm.assign(t, "a")
    alone = cm.slowdown(t, [])
    contended = cm.slowdown(t, ["b"])
    assert contended > alone            # co-location slows the live host
    assert alone >= 1.0


def test_cache_overflow_penalty():
    cm = CellManager()
    cm.create("small", ways=2, working_set_frac=0.8, bw_demand=0.1)
    cm.create("big", ways=10, working_set_frac=0.8, bw_demand=0.1)
    ts = VTask("s", None, kind="live")
    tb = VTask("b", None, kind="live")
    cm.assign(ts, "small")
    cm.assign(tb, "big")
    assert cm.slowdown(ts, []) > cm.slowdown(tb, [])


def test_temporal_residue_reconditioning():
    cm = CellManager(n_warm_slots=1, recondition_ns=10_000)
    cm.create("a")
    cm.create("b")
    ta, tb = VTask("a", None, kind="live"), VTask("b", None, kind="live")
    cm.assign(ta, "a")
    cm.assign(tb, "b")
    c1 = cm.switch_cost(ta)        # cold
    assert c1 > 0
    assert cm.switch_cost(ta) == 0  # warm now
    c2 = cm.switch_cost(tb)        # evicts a
    assert c2 > 0
    c3 = cm.switch_cost(ta)        # a was evicted -> recondition again
    assert c3 > 0
    assert cm.stats["switches"] == 3


def test_residue_is_deterministic():
    assert _hash01(3, 7) == _hash01(3, 7)
    assert -1.0 <= _hash01(123, 456) < 1.0


def test_interference_folded_into_vtime():
    """Imperfect isolation is not hidden — it lands in simulated time."""
    cm = CellManager(recondition_ns=0)
    cm.create("noisy", ways=2, bw_share=0.3, bw_demand=0.9, mem_frac=1.0,
              working_set_frac=0.9)
    cm.create("victim", ways=2, bw_share=0.3, bw_demand=0.9, mem_frac=1.0,
              working_set_frac=0.9)
    sched = Scheduler(n_cpus=2, cells=cm)

    def live_body():
        for _ in range(3):
            yield LiveCall(lambda: sum(range(100)), cost_ns=100 * US)

    v = VTask("victim", live_body(), kind="live")
    n = VTask("noisy", live_body(), kind="live")
    cm.assign(v, "victim")
    cm.assign(n, "noisy")
    sched.spawn(v)
    sched.spawn(n)
    sched.run()
    # with a co-active noisy neighbor, vtime > pure cost
    assert v.vtime > 3 * 100 * US
    assert cm.stats["interference_events"] > 0


def test_isolated_cell_runs_at_cost():
    cm = CellManager(recondition_ns=0)
    cm.create("iso", ways=12, bw_share=1.0, bw_demand=0.2, mem_frac=0.3,
              working_set_frac=0.3)
    sched = Scheduler(n_cpus=1, cells=cm)

    def live_body():
        yield LiveCall(lambda: 1, cost_ns=100 * US)

    t = VTask("t", live_body(), kind="live")
    cm.assign(t, "iso")
    sched.spawn(t)
    sched.run()
    assert t.vtime == 100 * US


# -- state model: assignment-keyed live-cell multiset -------------------------


def test_indexed_coactive_matches_explicit_list():
    """The engine hot path (no explicit coactive list) reads the
    per-host live-cell multiset; it must price exactly what an explicit
    list of every other assigned cell prices.  (Power-of-two shares so
    aggregate-minus-own equals the explicit sum bit-exactly.)"""
    cm = CellManager()
    specs = dict(ways=4, bw_share=0.25, bw_demand=0.5, mem_frac=0.5,
                 working_set_frac=0.5)
    tasks = []
    for n in ("a", "b", "c"):
        cm.create(n, **specs)
        t = VTask(f"t.{n}", None, kind="live")
        cm.assign(t, n)
        tasks.append(t)
    ta = tasks[0]
    assert cm.slowdown(ta) == cm.slowdown(ta, ["b", "c"])
    assert cm.slowdown(ta) > cm.slowdown(ta, [])


def test_release_stops_interference():
    cm = CellManager()
    specs = dict(ways=4, bw_share=0.3, bw_demand=0.6, mem_frac=0.5,
                 working_set_frac=0.2)
    cm.create("a", **specs)
    cm.create("b", **specs)
    ta, tb = VTask("a", None, kind="live"), VTask("b", None, kind="live")
    cm.assign(ta, "a")
    cm.assign(tb, "b")
    contended = cm.slowdown(ta)
    cm.release("b")
    assert cm.slowdown(ta) < contended      # multiset updated


def test_release_clears_task_backrefs():
    """A released cell must stop charging its tasks even if the same
    name is created again later — stale ``task.cell`` backrefs used to
    silently bind old tasks to the new cell."""
    cm = CellManager(n_warm_slots=2)
    cm.create("a", ways=2, working_set_frac=0.9)
    t = VTask("t", None, kind="live")
    cm.assign(t, "a")
    assert cm.slowdown(t) > 1.0
    cm.release("a")
    assert t.cell is None
    # same name, different (benign) knobs: the old task must not
    # resurrect into it
    cm.create("a", ways=12, working_set_frac=0.1)
    assert cm.slowdown(t) == 1.0
    assert cm.switch_cost(t) == 0
    t2 = VTask("t2", None, kind="live")
    cm.assign(t2, "a")
    assert cm.switch_cost(t2) > 0           # the new cell works


def test_switch_counter_unified():
    """``stats["switches"]`` is the one switch counter (the old manager
    kept a second private ``_switches`` that double-counted into the
    residue hash)."""
    cm = CellManager(n_warm_slots=1, recondition_ns=10_000)
    cm.create("a")
    cm.create("b")
    ta, tb = VTask("a", None, kind="live"), VTask("b", None, kind="live")
    cm.assign(ta, "a")
    cm.assign(tb, "b")
    for _ in range(2):
        cm.switch_cost(ta)
        cm.switch_cost(tb)
    assert not hasattr(cm, "_switches")
    assert cm.stats["switches"] == 4
    snap = cm.snapshot()
    assert snap["switches"] == 4
    assert snap["cells"]["a"]["switches"] == 2
    assert snap["cells"]["b"]["switches"] == 2
    assert snap["recondition_ns"] == sum(
        c["recondition_ns"] for c in snap["cells"].values())


def test_residue_is_process_independent():
    """Reconditioning residues key on the task *name* + its own cold
    ordinal — never on vtask ids (which drift across builds in one
    process) or a shared counter (which drifts with interleaving)."""
    def charges():
        cm = CellManager(n_warm_slots=1, recondition_ns=10_000)
        cm.create("a")
        cm.create("b")
        ta = VTask("w0", None, kind="live")
        tb = VTask("w1", None, kind="live")
        cm.assign(ta, "a")
        cm.assign(tb, "b")
        return [cm.switch_cost(t) for t in (ta, tb, ta, tb)]

    assert charges() == charges()   # ids advanced; charges must not


def test_interference_vs_self_pressure_split():
    """A solo working-set overflow is not "interference among
    co-located live hosts": s > 1.0 with no co-active cells must land
    in ``self_pressure_events``, not ``interference_events``."""
    cm = CellManager()
    cm.create("solo", ways=2, working_set_frac=0.9, bw_share=0.3,
              bw_demand=0.5, mem_frac=0.3)
    t = VTask("t", None, kind="live")
    cm.assign(t, "solo")
    assert cm.slowdown(t) > 1.0             # cache overflow, alone
    assert cm.stats["interference_events"] == 0
    assert cm.stats["self_pressure_events"] == 1
    # now add a contending neighbor: the *extra* multiplier is
    # interference, and both counters advance independently
    cm.create("noisy", ways=2, bw_share=0.3, bw_demand=0.9,
              mem_frac=1.0, working_set_frac=0.9)
    tn = VTask("n", None, kind="live")
    cm.assign(tn, "noisy")
    cm.create("quiet", ways=12, working_set_frac=0.1, bw_share=0.5,
              bw_demand=0.05, mem_frac=0.1)
    tq = VTask("q", None, kind="live")
    cm.assign(tq, "quiet")
    s = cm.slowdown(t)
    assert s > 1.0
    assert cm.stats["interference_events"] == 1
    assert cm.stats["self_pressure_events"] == 2
    # the quiet cell gets its (tiny) demand even under contention:
    # neither self-pressured nor interfered with
    cm.slowdown(tq)
    assert cm.stats["interference_events"] == 1
    assert cm.stats["self_pressure_events"] == 2


# -- warm-slot eviction order under the indexed scheduler ---------------------


def test_warm_slot_eviction_order_under_indexed_dispatch():
    """Three cells cycling through two warm slots: the indexed
    scheduler dispatches in (vtime, id) order, so every live call finds
    its cell evicted (LRU churn) and the final warm set is the last two
    cells in dispatch order."""
    cm = CellManager(n_warm_slots=2, recondition_ns=0)
    for n in ("a", "b", "c"):
        cm.create(n, ways=12, working_set_frac=0.1, bw_demand=0.1,
                  bw_share=0.5, mem_frac=0.1)
    sched = Scheduler(n_cpus=1, cells=cm)

    def live_body():
        for _ in range(2):
            yield LiveCall(lambda: 1, cost_ns=100 * US)

    for n in ("a", "b", "c"):
        t = VTask(n, live_body(), kind="live")
        cm.assign(t, n)
        sched.spawn(t)
    sched.run()
    # dispatch order: a@0 b@0 c@0 (id ties) then a@100us b@100us
    # c@100us; with 2 slots over a 3-cycle every entry is cold
    assert cm.stats["switches"] == 6
    snap = cm.snapshot()
    assert [snap["cells"][n]["switches"] for n in "abc"] == [2, 2, 2]
    assert cm.warm_cells == ("b", "c")   # LRU-first after the last round


def test_warm_hit_keeps_slot_warm():
    """Back-to-back calls from the same cell are warm (move-to-end, no
    recharge), and a warm hit refreshes recency for LRU eviction."""
    cm = CellManager(n_warm_slots=2, recondition_ns=10_000)
    for n in ("a", "b", "c"):
        cm.create(n)
    ta = VTask("a", None, kind="live")
    tb = VTask("b", None, kind="live")
    tc = VTask("c", None, kind="live")
    for t, n in ((ta, "a"), (tb, "b"), (tc, "c")):
        cm.assign(t, n)
    assert cm.switch_cost(ta) > 0        # warm: [a]
    assert cm.switch_cost(tb) > 0        # warm: [a, b]
    assert cm.switch_cost(ta) == 0       # hit refreshes a: [b, a]
    assert cm.switch_cost(tc) > 0        # evicts b (LRU): [a, c]
    assert cm.warm_cells == ("a", "c")
    assert cm.switch_cost(tb) > 0        # b was evicted -> cold again


def test_assign_is_idempotent_and_constructor_label_registers():
    """assign() keys membership on the manager's own records, not on
    ``task.cell`` — so a task pre-labelled via ``VTask(cell=...)``
    still enters the live-cell multiset, and double-assign does not
    double-count."""
    cm = CellManager()
    cm.create("a", bw_demand=0.8, bw_share=0.5, working_set_frac=0.2,
              mem_frac=0.5)
    cm.create("b", bw_demand=0.8, bw_share=0.5, working_set_frac=0.2,
              mem_frac=0.5)
    ta = VTask("ta", None, kind="live", cell="a")   # constructor label
    cm.assign(ta, "a")
    cm.assign(ta, "a")
    assert cm._assigned == {"a": 1}
    tb = VTask("tb", None, kind="live")
    cm.assign(tb, "b")
    # both registered: 1.6 total demand > 1.0 -> real contention
    assert cm.slowdown(ta) == cm.slowdown(ta, ["b"]) > 1.0
    assert cm.stats["interference_events"] > 0


def test_constructor_cell_registers_on_spawn():
    """The core-API path — ``sched.spawn(VTask(..., cell=...))`` with
    no explicit assign() — must produce spatial interference exactly
    like assigned tasks (the multiset rewrite must not silently drop
    it); an unknown name keeps the lenient core no-op."""
    cm = CellManager(recondition_ns=0)
    specs = dict(bw_demand=0.8, bw_share=0.5, working_set_frac=0.2,
                 mem_frac=1.0)
    cm.create("a", **specs)
    cm.create("b", **specs)
    sched = Scheduler(n_cpus=1, cells=cm)

    def live_body():
        yield LiveCall(lambda: 1, cost_ns=100 * US)

    ta = VTask("ta", live_body(), kind="live", cell="a")
    tb = VTask("tb", live_body(), kind="live", cell="b")
    tu = VTask("tu", live_body(), kind="live", cell="unknown")
    for t in (ta, tb, tu):
        sched.spawn(t)
    sched.run()
    assert cm._assigned == {"a": 1, "b": 1}
    assert cm.stats["interference_events"] > 0
    assert ta.vtime > 100 * US          # contention landed in vtime
    assert tu.vtime == 100 * US         # unknown cell: lenient no-op


def test_host_spec_cell_manager_wiring():
    """Hand-wired orchestration path: HostSpec carries per-host cell
    allocations and from_host_specs builds one manager per host."""
    from repro.core import Cell
    from repro.core.orchestrator import HostSpec, Orchestrator

    specs = [HostSpec(0, n_cpus=2, cells=(Cell("a", ways=2),)),
             HostSpec(1, n_cpus=4)]
    orch = Orchestrator.from_host_specs(
        specs, cell_knobs=dict(n_warm_slots=2))
    assert orch.hosts[0].n_cpus == 2
    assert orch.hosts[1].n_cpus == 4
    assert list(orch.hosts[0].cells.cells) == ["a"]
    assert orch.hosts[0].cells.host == 0
    assert orch.hosts[0].cells.n_warm_slots == 2
    assert orch.hosts[1].cells.cells == {}
    with pytest.raises(ValueError, match="host ids"):
        Orchestrator.from_host_specs([HostSpec(1), HostSpec(2)])


# -- facade: declarations, validation, report ---------------------------------


def _cells_topo():
    topo = Topology.single_host(n_cpus=1)
    topo.cell("hot", ways=2, working_set_frac=0.7, bw_share=0.3,
              bw_demand=0.7, mem_frac=0.6)
    topo.cell("cold", ways=8, working_set_frac=0.3, bw_share=0.5,
              bw_demand=0.4, mem_frac=0.2)
    return topo


def test_undeclared_cell_is_a_build_error():
    """Satellite bugfix: a Program.cell naming an undeclared cell used
    to silently no-op (slowdown 1.0 / switch cost 0) — through the
    facade it is now a build-time error."""
    wl = RackRing(n_racks=1, hosts_per_rack=2, n_iters=2, live=True,
                  cells={"w0": "typo"})
    sim = Simulation(_cells_topo(), wl)
    with pytest.raises(ValueError, match="undeclared cell"):
        sim.build()


def test_undeclared_interference_cell_is_a_build_error():
    wl = RackRing(n_racks=1, hosts_per_rack=2, n_iters=2)
    sim = Simulation(
        _cells_topo(), wl,
        Scenario("noisy", (Interference(host=0, cell="typo"),)))
    with pytest.raises(ValueError, match="undeclared cell"):
        sim.build()


def test_core_still_masks_unknown_cell():
    """The core manager keeps the lenient semantics the facade now
    guards against (documents exactly what the old silent no-op masked:
    a typo'd cell priced nothing)."""
    cm = CellManager()
    t = VTask("t", None, kind="live")
    t.cell = "typo"
    assert cm.slowdown(t, []) == 1.0
    assert cm.switch_cost(t) == 0


def test_facade_builds_per_host_managers_and_reports():
    cells = {"w0": "hot", "w1": "cold", "w2": "hot", "w3": "cold"}
    wl = RackRing(n_racks=2, hosts_per_rack=2, n_iters=10,
                  compute_ns=30_000, live=True, cells=cells,
                  skew_bound_ns=2_000_000)
    topo = Topology(n_hosts=2, n_cpus=1)
    topo.cell("hot", ways=2, working_set_frac=0.7, bw_share=0.3,
              bw_demand=0.7, mem_frac=0.6)
    topo.cell("cold", ways=8, working_set_frac=0.3, bw_share=0.5,
              bw_demand=0.2, mem_frac=0.2)
    topo.cell_config(n_warm_slots=1, recondition_ns=25_000)
    sim = Simulation(topo, wl,
                     placement={"w0": 0, "w1": 0, "w2": 1, "w3": 1})
    report = sim.run()
    # one manager per host, cell state independent per host
    assert sorted(sim.cell_managers) == [0, 1]
    assert sim.cell_managers[0] is not sim.cell_managers[1]
    assert sim.cell_managers[0].n_warm_slots == 1
    assert sorted(report.cells) == ["0", "1"]
    for host in ("0", "1"):
        assert report.cells[host]["switches"] > 0
        assert report.cells[host]["cells"]["hot"]["live_calls"] == 10
    # the report is JSON-clean
    report.to_json()


def test_interference_cell_slows_victim_without_cpu_resource():
    """The cell axis of Interference: a modeled load bound to a
    declared cell spatially interferes with a co-located live victim —
    no simulated-CPU queuing required."""
    def run(scenario):
        wl = RackRing(n_racks=1, hosts_per_rack=1, n_iters=10,
                      compute_ns=100_000, live=True,
                      cells={"w0": "hot"})
        return Simulation(_cells_topo(), wl, scenario).run()

    quiet = run(Scenario("quiet"))
    noisy = run(Scenario("noisy", (
        Interference(co_locate_with="w0", cell="cold", bursts=5),)))
    assert noisy.tasks["w0"]["vtime"] > quiet.tasks["w0"]["vtime"]
    assert noisy.cells["0"]["interference_events"] > 0
    assert quiet.cells["0"]["interference_events"] == 0


def test_auto_cells_for_colocated_placements():
    """``cells="auto"``: co-location implies a controlled resource
    domain — every co-located program (and interference load) gets a
    derived cell without explicit declarations."""
    wl = RackRing(n_racks=1, hosts_per_rack=2, n_iters=5,
                  compute_ns=50_000, live=True)
    sim = Simulation(
        Topology.single_host(n_cpus=1), wl,
        Scenario("noisy", (Interference(host=0, bursts=3),)),
        cells="auto")
    report = sim.run()
    cm = sim.cell_managers[0]
    assert sorted(cm.cells) == ["cell:load0", "cell:w0", "cell:w1"]
    assert sim.tasks[0].cell == "cell:w0"
    assert report.cells["0"]["cells"]["cell:w0"]["live_calls"] == 5
    # a lone program on its host derives nothing
    alone = Simulation(Topology.single_host(n_cpus=1),
                       RackRing(n_racks=1, hosts_per_rack=1,
                                n_iters=2, live=True),
                       cells="auto")
    alone.build()
    assert alone.cell_managers == {}
