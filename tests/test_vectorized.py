"""Vectorized-engine conformance: the two-tier contract of
``Simulation.run(engine="vectorized")`` (see tests/engine_harness.py),
the ``UnsupportedByEngine`` surface, the int32 tick-range guard, and
the ``Simulation.sweep`` vmap batch.
"""
import numpy as np
import pytest

from engine_harness import (assert_vectorized_exact,
                            assert_vectorized_tolerance,
                            assert_engines_agree, run_engine)
from repro.core.cluster import ClusterSpec, StepCost
from repro.core.engine_jax import INF_TICKS
from repro.sim import (ChipRingTraining, DegradeLink, FailHost,
                       FailTask, Interference, ModeledServe, RackRing,
                       Scenario, Simulation, Straggler, TickRangeError,
                       Topology, UnsupportedByEngine)


def rack_sim(sc=None, *, n_iters=12, skew=100_000, compute=5_000):
    def make():
        wl = RackRing(n_racks=2, hosts_per_rack=2, n_iters=n_iters,
                      compute_ns=compute, msg_bytes=4096, cross_every=4,
                      skew_bound_ns=skew)
        return Simulation(Topology.racks(2, 2), wl, sc)
    return make


def chip_sim(sc=None):
    def make():
        wl = ChipRingTraining(
            ClusterSpec(n_pods=2, chips_per_pod=4),
            StepCost(compute_ns=50_000, ici_bytes=8192,
                     dcn_bytes=65536), n_steps=5,
            skew_bound_ns=1_000_000)
        return Simulation(
            Topology.full_mesh(2, link=Topology().default_host_link),
            wl, sc, placement={f"chip{i}": i // 4 for i in range(8)})
    return make


# --------------------------------------------------------------------------
# exact tier
# --------------------------------------------------------------------------

class TestExactTier:
    def test_rack_baseline_all_engines(self):
        """Vectorized joins the full cross-engine bar (barrier, async,
        dist) on a clean multi-host scenario."""
        make = rack_sim()
        assert_engines_agree(make)
        assert_vectorized_exact(make, ref_engine="async")

    def test_single_host_vs_single_engine(self):
        def make():
            wl = RackRing(n_racks=1, hosts_per_rack=1, n_iters=8,
                          compute_ns=3_000)
            return Simulation(Topology.single_host(), wl)
        assert_vectorized_exact(make, ref_engine="single")
        assert_vectorized_exact(make, ref_engine="async")

    def test_chipring_two_pods(self):
        assert_vectorized_exact(chip_sim(), ref_engine="async")
        assert_vectorized_exact(chip_sim(), ref_engine="barrier")

    def test_straggler(self):
        sc = Scenario("s", (Straggler("w1", 2.5), Straggler("w1", 1.5)))
        assert_vectorized_exact(rack_sim(sc))

    def test_fail_host_deadlocks_identically(self):
        sc = Scenario("f", (FailHost(1, at_vtime=160_000),))
        reports = assert_vectorized_exact(rack_sim(sc))
        assert reports["vectorized"].status == "deadlock"

    def test_fail_at_compute(self):
        sc = Scenario("fc", (FailTask("w2", at_compute=3),))
        reports = assert_vectorized_exact(rack_sim(sc))
        assert reports["vectorized"].tasks["w2"]["state"] == "done"

    def test_degrade_link_hosts(self):
        sc = Scenario("d", (DegradeLink(hosts=(0, 2), extra_ns=7_000,
                                        from_vtime=50_000),))
        assert_vectorized_exact(rack_sim(sc))

    def test_degrade_link_fabric(self):
        sc = Scenario("d", (DegradeLink(fabric="hub",
                                        latency_factor=3.0),))
        assert_vectorized_exact(rack_sim(sc))

    def test_interference_load(self):
        def make():
            wl = RackRing(n_racks=2, hosts_per_rack=1, n_iters=6,
                          compute_ns=4_000, cross_every=2)
            sc = Scenario("i", (Interference(host=1, bursts=5,
                                             burst_ns=2_000),))
            return Simulation(
                Topology.full_mesh(2,
                                   link=Topology().default_host_link),
                wl, sc)
        assert_vectorized_exact(make)

    def test_on_deadlock_raise(self):
        from repro.core.scheduler import DeadlockError
        sc = Scenario("f", (FailHost(1, at_vtime=160_000),))
        with pytest.raises(DeadlockError):
            rack_sim(sc)().run(engine="vectorized",
                               on_deadlock="raise")

    def test_pallas_interpret_matches_jnp(self):
        """The Pallas minskew/hub_route path (interpret mode on CPU)
        is bit-identical to the jnp fallback."""
        make = rack_sim(Scenario("s", (Straggler("w0", 1.75),)))
        off = make().run(engine="vectorized", pallas="off",
                         verify=True)
        interp = make().run(engine="vectorized", pallas="interpret",
                            verify=True)
        d_off, d_int = off.to_dict(), interp.to_dict()
        d_off["wall_s"] = d_int["wall_s"] = 0.0
        assert d_off == d_int


# --------------------------------------------------------------------------
# tolerance tier
# --------------------------------------------------------------------------

class TestToleranceTier:
    def test_quantized_rack(self):
        reports = assert_vectorized_tolerance(
            rack_sim(), tick_ns=100, vtime_tol_ns=20_000)
        assert reports["vectorized"].tier == "tolerance"
        assert reports["vectorized"].tick_ns == 100

    def test_quantized_with_faults(self):
        sc = Scenario("mix", (Straggler("w1", 2.5),
                              FailHost(3, at_vtime=200_000)))
        assert_vectorized_tolerance(rack_sim(sc), tick_ns=100,
                                    vtime_tol_ns=20_000)

    def test_divisible_explicit_tick_stays_exact(self):
        """An explicit tick that divides every ns quantity (computes,
        send overhead, serialization, latency) is still the exact tier
        — quantization is lossless."""
        def make():
            # local_link moves 80 bytes/ns, so 40000 bytes serialize in
            # exactly 500 ns; every quantity is a multiple of 500
            wl = RackRing(n_racks=1, hosts_per_rack=1, n_iters=8,
                          compute_ns=3_000, msg_bytes=40_000)
            return Simulation(Topology.single_host(), wl)
        rep = make().run(engine="vectorized", tick_ns=500,
                         verify=True)
        assert rep.tier == "exact"
        ref = make().run(engine="single")
        assert rep.vtime_ns == ref.vtime_ns
        assert rep.tasks == ref.tasks


# --------------------------------------------------------------------------
# UnsupportedByEngine surface
# --------------------------------------------------------------------------

class TestUnsupported:
    def test_live_program(self):
        wl = RackRing(n_racks=1, hosts_per_rack=2, n_iters=4,
                      live=True)
        sim = Simulation(Topology.full_mesh(
            2, link=Topology().default_host_link), wl)
        with pytest.raises(UnsupportedByEngine, match="live"):
            sim.run(engine="vectorized")

    def test_cells(self):
        topo = Topology.single_host()
        topo.cell("hot", ways=4)
        wl = RackRing(n_racks=1, hosts_per_rack=1, n_iters=4,
                      live=True, cells={"w0": "hot"})
        with pytest.raises(UnsupportedByEngine):
            Simulation(topo, wl).run(engine="vectorized")

    def test_auto_cells_colocation(self):
        wl = RackRing(n_racks=1, hosts_per_rack=2, n_iters=4)
        sim = Simulation(Topology.single_host(), wl, cells="auto")
        with pytest.raises(UnsupportedByEngine, match="cell"):
            sim.run(engine="vectorized")

    def test_cpu_resource(self):
        wl = RackRing(n_racks=1, hosts_per_rack=1, n_iters=4)
        sim = Simulation(Topology.single_host(), wl, cpu_resource=True)
        with pytest.raises(UnsupportedByEngine, match="cpu_resource"):
            sim.run(engine="vectorized")

    def test_workload_without_lowering(self):
        """ModeledServe has no vec_ops: its server receives from many
        clients, so receive matching is schedule-dependent."""
        sim = Simulation(Topology.single_host(),
                         ModeledServe(n_clients=2, n_requests=3))
        with pytest.raises(UnsupportedByEngine, match="vec_ops"):
            sim.run(engine="vectorized")

    def test_reference_engines_unaffected(self):
        """Scenarios the vectorized engine rejects still run (and still
        agree) on the reference engines."""
        def make():
            wl = RackRing(n_racks=1, hosts_per_rack=1, n_iters=4)
            return Simulation(Topology.single_host(), wl,
                              cpu_resource=True)
        assert_engines_agree(make)


# --------------------------------------------------------------------------
# int32 tick-range guard (no silent overflow)
# --------------------------------------------------------------------------

class TestTickRange:
    def _big_ring(self):
        # two workers so the ring actually messages: the 500/51 ns
        # message quantities force the auto tick to 1, and the 2**30 ns
        # computes then blow the 2**30-tick horizon bound
        wl = RackRing(n_racks=1, hosts_per_rack=2, n_iters=2,
                      compute_ns=INF_TICKS)
        return Simulation(Topology.single_host(), wl)

    def test_horizon_over_range_raises(self):
        with pytest.raises(TickRangeError, match="tick_ns"):
            self._big_ring().run(engine="vectorized")

    def test_coarser_tick_recovers(self):
        """The error message's remedy works: a coarser explicit tick
        brings the same scenario back in range (tolerance tier, since
        the 500 ns send overhead does not divide 1024)."""
        rep = self._big_ring().run(engine="vectorized", tick_ns=1024)
        assert rep.status == "ok"
        assert rep.tier == "tolerance"
        # the reference engines run on python ints — no range limit
        ref = self._big_ring().run(engine="single")
        assert abs(rep.vtime_ns - ref.vtime_ns) <= 1024 * 16

    def test_boundary_is_tight(self):
        """A horizon bound just under 2**30 ticks (explicit tick_ns=1,
        so no gcd compression) compiles and runs; the guard is not
        spuriously conservative near the boundary."""
        def make():
            wl = RackRing(n_racks=1, hosts_per_rack=1, n_iters=1,
                          compute_ns=INF_TICKS - 2048)
            return Simulation(Topology.single_host(), wl)
        rep = make().run(engine="vectorized", tick_ns=1, verify=True)
        assert rep.status == "ok"
        assert rep.tier == "exact"
        assert rep.vtime_ns == make().run(engine="single").vtime_ns
        assert rep.vtime_ns == INF_TICKS - 2048

    def test_vecstate_create_boundary(self):
        """VecState.create (the raw array engine) validates
        durations*steps against the int32 range with an explicit
        error, instead of silently wrapping."""
        from repro.core.engine_jax import VecState
        n = 4
        member = np.ones((n, 1), bool)
        skews = np.array([1000])
        ok = VecState.create(n, 1, np.full(n, 2**20), np.full(n, 2**9),
                             member, skews)
        assert ok.vtime.shape == (n,)
        with pytest.raises(TickRangeError, match="task"):
            VecState.create(n, 1, np.full(n, 2**21), np.full(n, 2**9),
                            member, skews)

    def test_vecstate_create_rejects_negative(self):
        from repro.core.engine_jax import VecState
        with pytest.raises(ValueError):
            VecState.create(2, 1, np.array([-1, 5]), np.array([3, 3]),
                            np.ones((2, 1), bool), np.array([10]))


# --------------------------------------------------------------------------
# batched sweep
# --------------------------------------------------------------------------

class TestSweep:
    # stragglers change tape values, never tape shapes, so these share
    # scenario structure with the baseline
    AXIS = [Scenario("base"),
            Scenario("s1", (Straggler("w1", 2.0),)),
            Scenario("s2", (Straggler("w3", 3.0),
                            Straggler("w0", 1.5)))]

    def test_sweep_matches_solo_and_reference(self):
        res = rack_sim()().sweep(self.AXIS)
        assert res.tier == "exact"
        assert len(res.reports) == len(self.AXIS)
        assert res.configs_per_s > 0
        for sc, rep in zip(self.AXIS, res.reports):
            solo = rack_sim(sc)().run(engine="vectorized")
            d1, d2 = rep.to_dict(), solo.to_dict()
            d1["wall_s"] = d2["wall_s"] = 0.0
            assert d1 == d2, f"sweep vs solo diverged on {sc.name}"
            ref = run_engine(rack_sim(sc), "async")
            assert rep.vtime_ns == ref.vtime_ns
            assert rep.tasks == ref.tasks

    def test_sweep_degrade_axis(self):
        """Sweeping a DegradeLink knob: every variant carries the hook
        (same extras shape), only the added latency differs."""
        axis = [Scenario(f"d{e}", (DegradeLink(hosts=(0, 2),
                                               extra_ns=e),))
                for e in (0, 3_000, 11_000)]
        res = rack_sim()().sweep(axis)
        for sc, rep in zip(axis, res.reports):
            ref = run_engine(rack_sim(sc), "async")
            assert rep.vtime_ns == ref.vtime_ns, sc.name
            assert rep.tasks == ref.tasks, sc.name

    def test_sweep_with_kills(self):
        axis = [Scenario("base"),
                Scenario("f", (FailHost(1, at_vtime=160_000),))]
        res = rack_sim()().sweep(axis)
        assert res.reports[0].status == "ok"
        assert res.reports[1].status == "deadlock"
        ref = run_engine(rack_sim(axis[1]), "async")
        assert res.reports[1].tasks == ref.tasks

    def test_sweep_needs_shared_structure(self):
        axis = [Scenario("base"),
                Scenario("i", (Interference(host=0, bursts=3,
                                            burst_ns=1_000),))]
        with pytest.raises(UnsupportedByEngine, match="structure"):
            rack_sim()().sweep(axis)

    def test_sweep_empty_axis(self):
        with pytest.raises(ValueError):
            rack_sim()().sweep([])


# --------------------------------------------------------------------------
# compile-time validation parity with build()
# --------------------------------------------------------------------------

class TestValidationParity:
    def test_unknown_straggler_target(self):
        sc = Scenario("s", (Straggler("nope", 2.0),))
        with pytest.raises(ValueError, match="unknown"):
            rack_sim(sc)().run(engine="vectorized")

    def test_two_explicit_fails(self):
        sc = Scenario("f", (FailTask("w0", at_compute=1),
                            FailTask("w0", at_compute=2)))
        with pytest.raises(ValueError, match="two failures"):
            rack_sim(sc)().run(engine="vectorized")

    def test_degrade_needs_one_of(self):
        sc = Scenario("d", (DegradeLink(),))
        with pytest.raises(ValueError, match="exactly one"):
            rack_sim(sc)().run(engine="vectorized")

    def test_degrade_negative_extra(self):
        sc = Scenario("d", (DegradeLink(hosts=(0, 1),
                                        latency_factor=0.1),))
        with pytest.raises(ValueError, match="only add"):
            rack_sim(sc)().run(engine="vectorized")

    def test_failhost_out_of_range(self):
        sc = Scenario("f", (FailHost(99, at_vtime=1_000),))
        with pytest.raises(ValueError, match="FailHost"):
            rack_sim(sc)().run(engine="vectorized")

    def test_report_metadata(self):
        rep = rack_sim()().run(engine="vectorized")
        assert rep.mode == "vectorized"
        assert rep.tier == "exact"
        assert rep.tick_ns >= 1
        assert rep.sync_rounds > 0          # rounds of the jitted loop
        d = rep.to_dict()                   # JSON round-trip intact
        assert d["tier"] == "exact"
