"""Runtime integration: checkpoint/restart, failure injection, elastic
re-shard, gradient compression, serving loop, data determinism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import CheckpointManager, restore, save, latest_step
from repro.data import SyntheticLMData
from repro.launch.mesh import make_test_mesh
from repro.models import registry
from repro.runtime import FailureInjector, Trainer, TrainerConfig


@pytest.fixture()
def small_cfg():
    return dataclasses.replace(configs.get_smoke("qwen3_4b"),
                               remat=False)


def test_data_pipeline_deterministic_and_step_indexed():
    d1 = SyntheticLMData(vocab=64, seq_len=16, global_batch=4, seed=3)
    d2 = SyntheticLMData(vocab=64, seq_len=16, global_batch=4, seed=3)
    np.testing.assert_array_equal(d1.batch(7)["tokens"],
                                  d2.batch(7)["tokens"])
    assert not np.array_equal(d1.batch(7)["tokens"],
                              d1.batch(8)["tokens"])


def test_checkpoint_atomic_roundtrip(tmp_path, small_cfg):
    params = registry.init(small_cfg, jax.random.PRNGKey(0))
    save(tmp_path, {"params": params}, step=5, extra={"note": "x"})
    assert latest_step(tmp_path) == 5
    like = {"params": registry.init(small_cfg, jax.random.PRNGKey(1))}
    got, step, extra = restore(tmp_path, like)
    assert step == 5 and extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(
            {"params": params})):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_ignores_partial_writes(tmp_path, small_cfg):
    params = {"w": jnp.ones((4, 4))}
    save(tmp_path, params, step=1)
    # simulate a crashed (uncommitted) later checkpoint
    (tmp_path / "step_00000002.tmp").mkdir()
    (tmp_path / "step_00000002.tmp" / "leaf_00000.npy").write_bytes(b"junk")
    assert latest_step(tmp_path) == 1
    got, step, _ = restore(tmp_path, params)
    assert step == 1


def test_trainer_loss_decreases(small_cfg, tmp_path):
    tcfg = TrainerConfig(n_steps=30, seq_len=32, global_batch=4,
                         checkpoint_every=1000,
                         checkpoint_dir=str(tmp_path), log_every=1000)
    tr = Trainer(small_cfg, tcfg, log_fn=lambda s: None)
    out = tr.run()
    losses = [h["loss"] for h in out["history"]]
    assert len(losses) == 30
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_trainer_failure_restart_resumes_from_checkpoint(small_cfg,
                                                         tmp_path):
    tcfg = TrainerConfig(n_steps=25, seq_len=16, global_batch=4,
                         checkpoint_every=10, checkpoint_async=False,
                         checkpoint_dir=str(tmp_path), log_every=1000)
    inj = FailureInjector(fail_at_steps={17})
    tr = Trainer(small_cfg, tcfg, injector=inj, log_fn=lambda s: None)
    out = tr.run()
    assert out["restarts"] == 1
    # after failing at 17, resumed from the step-10 checkpoint
    steps = [h["step"] for h in out["history"]]
    assert steps.count(12) == 2          # re-executed after restore
    assert out["final_step"] == 25
    # deterministic data => the re-run of step 12 sees identical tokens
    d = tr.data
    np.testing.assert_array_equal(d.batch(12)["tokens"],
                                  d.batch(12)["tokens"])


def test_trainer_failure_without_checkpoint_restarts_fresh(small_cfg,
                                                           tmp_path):
    tcfg = TrainerConfig(n_steps=8, seq_len=16, global_batch=4,
                         checkpoint_every=100, checkpoint_dir=str(tmp_path),
                         log_every=1000)
    inj = FailureInjector(fail_at_steps={3})
    tr = Trainer(small_cfg, tcfg, injector=inj, log_fn=lambda s: None)
    out = tr.run()
    assert out["restarts"] == 1 and out["final_step"] == 8


def test_elastic_restore_across_meshes(tmp_path):
    """Save under a (2,1) mesh, restore under (1,2) — re-shard on load.

    Needs >1 device, so it runs in a subprocess with its own XLA_FLAGS
    (the main test process must keep the single real CPU device)."""
    import subprocess
    import sys

    prog = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, jax, numpy as np
from repro import configs
from repro.checkpoint import restore, save
from repro.launch.mesh import make_test_mesh
from repro.models import registry
from repro.train.step import train_state_shardings

small_cfg = dataclasses.replace(configs.get_smoke("qwen3_4b"), remat=False)
tmp = {str(tmp_path)!r}
mesh_a = make_test_mesh(data=2, model=1)
mesh_b = make_test_mesh(data=1, model=2)
params = registry.init(small_cfg, jax.random.PRNGKey(0))
p_sh_a, _ = train_state_shardings(small_cfg, mesh_a)
params_a = jax.device_put(params, p_sh_a)
save(tmp, params_a, step=1)
p_sh_b, _ = train_state_shardings(small_cfg, mesh_b)
like = registry.init(small_cfg, jax.random.PRNGKey(1))
got, _, _ = restore(tmp, like, shardings=p_sh_b)
for leaf, sh in zip(jax.tree.leaves(got), jax.tree.leaves(
        p_sh_b, is_leaf=lambda x: hasattr(x, "spec"))):
    assert leaf.sharding == sh, (leaf.sharding, sh)
for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(params)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("ELASTIC_OK")
"""
    env = {**__import__("os").environ, "PYTHONPATH": "src"}
    res = subprocess.run([sys.executable, "-c", prog], cwd="/root/repo",
                         env=env, capture_output=True, text=True,
                         timeout=300)
    assert "ELASTIC_OK" in res.stdout, res.stderr[-2000:]


def test_compressed_training_converges(small_cfg, tmp_path):
    tcfg = TrainerConfig(n_steps=25, seq_len=32, global_batch=4,
                         compress_grads=True, checkpoint_every=1000,
                         checkpoint_dir=str(tmp_path), log_every=1000)
    tr = Trainer(small_cfg, tcfg, log_fn=lambda s: None)
    out = tr.run()
    losses = [h["loss"] for h in out["history"]]
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_serve_loop(small_cfg):
    from repro.serve.loop import BatchServer

    params = registry.init(small_cfg, jax.random.PRNGKey(0))
    srv = BatchServer(small_cfg, params, max_new_tokens=8)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                 small_cfg.vocab)
    out = srv.generate(prompts)
    assert out["tokens"].shape == (2, 8)
    assert out["stats"].throughput_tok_s > 0
    # greedy decode must be reproducible
    out2 = srv.generate(prompts)
    np.testing.assert_array_equal(out["tokens"], out2["tokens"])


def test_serve_eos_masks_finished_lanes_and_early_exits(small_cfg):
    """Regression: post-EOS positions used to leak the finished lane's
    argmax (KV garbage) into the output, and per_token_ms divided by
    the full output width even when EOS early-exit ran fewer decode
    steps."""
    from repro.serve.loop import BatchServer

    params = registry.init(small_cfg, jax.random.PRNGKey(0))
    ref = BatchServer(small_cfg, params, max_new_tokens=8)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                 small_cfg.vocab)
    toks = np.asarray(ref.generate(prompts)["tokens"])

    # force a mid-sequence EOS: pick lane 0's second greedy token; the
    # eos-aware server must then pad lane 0 after that position
    eos = int(toks[0, 1])
    pad = -1
    srv = BatchServer(small_cfg, params, max_new_tokens=8, eos_id=eos,
                      pad_id=pad)
    out = srv.generate(prompts)
    got = np.asarray(out["tokens"])
    stats = out["stats"]
    for lane in range(got.shape[0]):
        hits = np.where(got[lane] == eos)[0]
        if len(hits):
            after = got[lane, hits[0] + 1:]
            assert (after == pad).all(), (
                f"lane {lane} leaks unmasked post-EOS tokens: "
                f"{got[lane]}")
    # tokens_out counts only live-lane emissions, never pad filler
    assert stats.tokens_out <= got.size
    assert stats.tokens_out < toks.size or (got != pad).all()
    assert stats.decode_steps <= got.shape[1] - 1
    # per_token_ms is per decode step actually executed
    assert stats.per_token_ms == pytest.approx(
        stats.decode_s / max(stats.decode_steps, 1) * 1e3)

    # forced immediate EOS on every lane: decode must early-exit after
    # the prefill token, not run max_new-1 garbage steps
    eos_all = int(toks[0, 0])
    if int(toks[1, 0]) == eos_all:
        srv2 = BatchServer(small_cfg, params, max_new_tokens=8,
                           eos_id=eos_all, pad_id=pad)
        out2 = srv2.generate(prompts)
        assert out2["stats"].decode_steps == 0
        assert np.asarray(out2["tokens"]).shape[1] == 1

    # single-lane early exit: batch of one, EOS = its first decoded
    # token -> exactly one decode step, output width 2
    one = prompts[:1]
    first = np.asarray(ref.generate(one)["tokens"])[0]
    srv3 = BatchServer(small_cfg, params, max_new_tokens=8,
                       eos_id=int(first[1]), pad_id=pad)
    out3 = srv3.generate(one)
    got3 = np.asarray(out3["tokens"])
    assert got3.shape[1] < 8, "EOS early-exit did not trigger"
    assert out3["stats"].decode_steps == got3.shape[1] - 1
