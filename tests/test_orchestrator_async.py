"""Async per-link-lookahead orchestration engine (paper §3.5).

Covers the conservative-PDES guarantees the engine is built on:
cross-host visibility always respects the per-link latency, lazy proxy
syncs keep staleness bounded, heterogeneous-latency topologies produce
identical results in ``barrier`` and ``async`` modes (in fewer
synchronization rounds), and a wedged cluster raises DeadlockError in
both modes.
"""
import pytest

from repro.core import (Compute, DeadlockError, Endpoint, Hub, LinkSpec,
                        Orchestrator, Recv, Send, State, US, VTask)

INTRA_NS = 2 * US           # fast intra-rack interconnect
CROSS_NS = 50 * US          # slow cross-rack interconnect


def fast_hub(name="hub", lat_ns=500):
    return Hub(name, LinkSpec(bandwidth_bps=80e9 * 8, latency_ns=lat_ns))


def make_rack_pair_orch(mode):
    """4 hosts in 2 racks: (0,1) and (2,3) share fast links; rack-to-rack
    pairs share slow links."""
    orch = Orchestrator(n_hosts=4, n_cpus=2, mode=mode)
    intra = LinkSpec(bandwidth_bps=80e9 * 8, latency_ns=INTRA_NS)
    cross = LinkSpec(bandwidth_bps=25e9 * 8, latency_ns=CROSS_NS)
    orch.connect_hosts(0, 1, intra)
    orch.connect_hosts(2, 3, intra)
    for a in (0, 1):
        for b in (2, 3):
            orch.connect_hosts(a, b, cross)
    hubs = [orch.add_hub(h, fast_hub(f"hub{h}")) for h in range(4)]
    return orch, hubs


def spawn_pingpong(orch, hubs, a, b, n, tag, size=256):
    """A request/response pair between hosts a and b."""
    ep_a = hubs[a].attach(Endpoint(f"{tag}.a"))
    ep_b = hubs[b].attach(Endpoint(f"{tag}.b"))

    def client():
        for i in range(n):
            yield Compute(5 * US)
            yield Send(ep_a, f"{tag}.b", size, payload=i)
            yield Recv(ep_a)

    def server():
        for _ in range(n):
            msg = yield Recv(ep_b)
            yield Compute(1 * US)
            yield Send(ep_b, f"{tag}.a", size, payload=msg.payload)

    c = orch.host(a).spawn(VTask(f"{tag}.c", client(), kind="modeled"))
    s = orch.host(b).spawn(VTask(f"{tag}.s", server(), kind="modeled"))
    return c, s


def build_hetero_workload(mode):
    """Chatty intra-rack pingpong + occasional cross-rack pingpong: the
    topology where per-link lookahead beats the global-min window."""
    orch, hubs = make_rack_pair_orch(mode)
    tasks = []
    tasks += spawn_pingpong(orch, hubs, 0, 1, n=40, tag="r0")
    tasks += spawn_pingpong(orch, hubs, 2, 3, n=40, tag="r1")
    tasks += spawn_pingpong(orch, hubs, 0, 2, n=4, tag="xr")
    return orch, hubs, tasks


# -- per-link visibility ------------------------------------------------------

def test_cross_host_never_visible_before_link_latency():
    orch, hubs, tasks = build_hetero_workload("async")
    orch.run()
    assert all(t.state == State.DONE for t in tasks)
    # per-link accounting: visibility >= send_vtime + that link's latency
    checked = 0
    for hub in hubs:
        for peer, st in hub.peer_stats.items():
            assert st["messages"] > 0
            assert st["min_slack_ns"] >= 0, (hub.name, peer, st)
            checked += 1
    assert checked >= 4      # both rack pairs + the cross-rack pair, 2 dirs


def test_receiver_vtime_includes_per_link_latency():
    orch, hubs = make_rack_pair_orch("async")
    c, s = spawn_pingpong(orch, hubs, 0, 2, n=3, tag="x")
    orch.run()
    # three round trips over the slow cross-rack link
    assert c.vtime >= 3 * 2 * CROSS_NS


# -- lazy proxy sync / staleness ---------------------------------------------

def test_proxy_staleness_bounded_and_skew_preserved():
    skew = 100 * US
    step = 10 * US
    orch, hubs = make_rack_pair_orch("async")

    def worker(n):
        def body():
            for _ in range(n):
                yield Compute(step)
        return body()

    members = [orch.host(h).spawn(
        VTask(f"w{h}", worker(60), kind="modeled")) for h in range(4)]
    orch.global_scope("g", members, skew_bound_ns=skew)
    orch.run()
    assert all(t.state == State.DONE for t in members)
    for p in orch.proxies:
        # a proxy mirror may lag its source but never lead it
        assert p.vtime <= p.remote.vtime
        assert p.last_sync_vtime is not None and p.sync_count > 0
    # staleness at any sync is bounded by what the remote could cover
    # between syncs: one lookahead window plus the skew slack plus one
    # action granularity
    assert orch.stats["max_proxy_staleness_ns"] <= \
        skew + orch.stats["max_window_ns"] + step
    # the bounded-skew contract itself held on every host
    for sched in orch.hosts.values():
        assert sched.stats.max_skew_seen <= skew


def test_lazy_sync_does_fewer_proxy_syncs_than_barrier():
    skew = 100 * US

    def build(mode):
        orch, hubs = make_rack_pair_orch(mode)
        members = [orch.host(h).spawn(
            VTask(f"w{h}", (Compute(10 * US) for _ in range(60)),
                  kind="modeled")) for h in range(4)]
        orch.global_scope("g", members, skew_bound_ns=skew)
        return orch, members

    res = {}
    for mode in ("barrier", "async"):
        orch, members = build(mode)
        orch.run()
        assert all(t.state == State.DONE for t in members)
        res[mode] = orch.stats["proxy_syncs"]
    assert res["async"] < res["barrier"]


# -- mode equivalence on heterogeneous topologies -----------------------------

def test_hetero_topology_identical_results_both_modes():
    outcomes = {}
    for mode in ("barrier", "async"):
        orch, hubs, tasks = build_hetero_workload(mode)
        res = orch.run()
        assert all(t.state == State.DONE for t in tasks)
        outcomes[mode] = {
            "vtimes": [t.vtime for t in tasks],
            "msgs": res["messages"],
            "cross": orch.stats["cross_host_msgs"],
            "epochs": res["epochs"],
        }
    b, a = outcomes["barrier"], outcomes["async"]
    assert a["vtimes"] == b["vtimes"]
    assert a["msgs"] == b["msgs"]
    assert a["cross"] == b["cross"]
    # per-link lookahead needs fewer synchronization rounds than the
    # global-min-latency barrier on a heterogeneous topology
    assert a["epochs"] < b["epochs"]


def test_scope_only_coupling_no_hubs():
    """Hosts coupled purely by a global scope (no hubs at all) still
    complete in async mode — unbounded windows, lazy syncs only."""
    orch = Orchestrator(n_hosts=2, n_cpus=1, mode="async")
    fast = orch.host(0).spawn(VTask(
        "fast", (Compute(10 * US) for _ in range(50)), kind="modeled"))
    slow = orch.host(1).spawn(VTask(
        "slow", (Compute(40 * US) for _ in range(50)), kind="modeled"))
    orch.global_scope("g", [fast, slow], skew_bound_ns=80 * US)
    orch.run()
    assert fast.state == State.DONE and slow.state == State.DONE
    assert fast.vtime == 50 * 10 * US
    assert slow.vtime == 50 * 40 * US


@pytest.mark.parametrize("rx_host", [0, 2])
def test_multi_sender_endpoint_wakes_in_visibility_order(rx_host):
    """A receiver with two senders over links of very different latency:
    the slow message is *delivered* first (wall order) but the fast one
    becomes *visible* first (virtual order).  A wake-up — or a runnable
    Recv's idle-advance — past the strict window would timestamp the
    receiver against the slow message; both engines must instead receive
    the fast message at its own visibility.  ``rx_host`` places the
    receiver before (0) or after (2) the senders in round order: the
    former exercises the blocked-wake path, the latter the
    dispatch-time Recv path."""
    results = {}
    for mode in ("barrier", "async"):
        orch = Orchestrator(n_hosts=3, n_cpus=1, mode=mode)
        fast = LinkSpec(bandwidth_bps=80e9 * 8, latency_ns=INTRA_NS)
        slow = LinkSpec(bandwidth_bps=80e9 * 8, latency_ns=CROSS_NS)
        f_host, s_host = [h for h in range(3) if h != rx_host]
        orch.connect_hosts(rx_host, f_host, fast)
        orch.connect_hosts(rx_host, s_host, slow)
        orch.connect_hosts(f_host, s_host, slow)
        hubs = [orch.add_hub(h, fast_hub(f"hub{h}", lat_ns=0))
                for h in range(3)]
        rx = hubs[rx_host].attach(Endpoint("rx"))
        s1 = hubs[f_host].attach(Endpoint("s1"))
        s2 = hubs[s_host].attach(Endpoint("s2"))
        got = []

        def receiver():
            for _ in range(2):
                msg = yield Recv(rx)
                yield Compute(1 * US)   # timed work between receives:
                # a premature wake would corrupt this intermediate vtime
                # even when the final receive order converges
                got.append((msg.payload, msg.visibility_time))

        def slow_sender():          # sends at t=0 over the 50us link
            yield Send(s2, "rx", 64, payload="slow")

        def fast_sender():          # sends at t=5us over the 2us link
            yield Compute(5 * US)
            yield Send(s1, "rx", 64, payload="fast")

        r = orch.host(rx_host).spawn(
            VTask("r", receiver(), kind="modeled"))
        orch.host(f_host).spawn(VTask("f", fast_sender(), kind="modeled"))
        orch.host(s_host).spawn(VTask("s", slow_sender(), kind="modeled"))
        for h in orch.hosts.values():
            h.send_overhead_ns = 0
        orch.run()
        assert r.state == State.DONE
        results[mode] = {"order": [p for p, _ in got],
                         "rx_vtime": r.vtime}
        # fast message first, despite the slow one being sent earlier
        assert results[mode]["order"] == ["fast", "slow"], (mode, got)
        # the receiver's intermediate Compute ran right after the fast
        # receive (~7us), not at the slow message's 50us visibility
        assert r.vtime == CROSS_NS + 1 * US, (mode, r.vtime)
        got.clear()
    assert results["barrier"] == results["async"]


def test_connect_hosts_after_add_hub_repins_link():
    orch = Orchestrator(n_hosts=2, n_cpus=1, mode="async")
    h0 = orch.add_hub(0, fast_hub("h0"))
    h1 = orch.add_hub(1, fast_hub("h1"))
    assert h0.peer_links["h1"].latency_ns == orch.dcn_link.latency_ns
    late = LinkSpec(bandwidth_bps=80e9 * 8, latency_ns=INTRA_NS)
    orch.connect_hosts(0, 1, late)      # after add_hub: must re-pin
    assert h0.peer_links["h1"].latency_ns == INTRA_NS
    assert h1.peer_links["h0"].latency_ns == INTRA_NS


# -- deadlock ----------------------------------------------------------------

@pytest.mark.parametrize("mode", ["barrier", "async"])
def test_wedged_cluster_raises_deadlock(mode):
    orch = Orchestrator(n_hosts=2, n_cpus=1, mode=mode)
    hub0 = orch.add_hub(0, fast_hub("hub0"))
    hub1 = orch.add_hub(1, fast_hub("hub1"))
    ep0 = hub0.attach(Endpoint("w0"))
    ep1 = hub1.attach(Endpoint("w1"))

    def waiter(ep):
        yield Recv(ep)      # nobody ever sends

    orch.host(0).spawn(VTask("w0t", waiter(ep0), kind="modeled"))
    orch.host(1).spawn(VTask("w1t", waiter(ep1), kind="modeled"))
    with pytest.raises(DeadlockError):
        orch.run()


# -- incremental LBTS solver --------------------------------------------------

def test_lbts_solver_matches_reference():
    """The vectorized min-plus-closure solver (LBTSSolver) must produce
    bit-identical clock bounds and earliest-input times to the
    reference relaxation on arbitrary graphs — including unreachable
    hosts, None next-times, asymmetric links, and repeated queries with
    changed/unchanged inputs (the incremental cache)."""
    import random

    from repro.core.orchestrator import (LBTSSolver, earliest_input_time,
                                         lbts_bounds)

    rng = random.Random(7)
    for trial in range(30):
        n = rng.choice((1, 2, 3, 5, 8, 13))
        hosts = list(range(n))
        lookahead = {}
        for s in hosts:
            for d in hosts:
                if s != d and rng.random() < 0.5:
                    lookahead[(s, d)] = rng.choice(
                        (1, 500, 2_000, 50_000))
        solver = LBTSSolver(lookahead, hosts)
        for _ in range(3):      # repeat: exercises the unchanged cache
            next_times = {h: (None if rng.random() < 0.3
                              else rng.randrange(0, 10_000_000))
                          for h in hosts}
            want_lb = lbts_bounds(next_times, lookahead)
            got_lb = solver.bounds(next_times)
            assert got_lb == want_lb, (trial, lookahead, next_times)
            for h in hosts:
                assert solver.eit(h, got_lb) == earliest_input_time(
                    h, want_lb, lookahead), (trial, h)
            # and again with identical inputs (cache hit path)
            assert solver.bounds(next_times) == want_lb


def test_quiescent_skip_preserves_results():
    """A quiescent-host skip must be invisible: the async engine with
    skipping produces the exact per-task timings of the barrier engine
    (which never skips)."""
    from repro.sim import RackRing, Scenario, Simulation, Straggler, Topology

    def make():
        wl = RackRing(n_racks=2, hosts_per_rack=2, n_iters=40,
                      skew_bound_ns=2_000_000)
        return Simulation(Topology.racks(2, 2), wl,
                          Scenario("imb", (Straggler("w2", 3.0),)),
                          placement=wl.default_placement())

    a = make().run(engine="async", on_deadlock="raise")
    b = make().run(engine="barrier", on_deadlock="raise")
    assert a.tasks == b.tasks
    assert a.messages == b.messages
