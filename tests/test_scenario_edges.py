"""Scenario-injection edge cases the main suites leave uncovered.

* ``DegradeLink.from_vtime`` landing exactly on a synchronization
  window boundary (the cross-rack lookahead and its multiples) — the
  >=-vs-> boundary must bind identically under every engine, or a
  degraded message could be charged in one engine and not another.
* ``Interference`` on a host whose victim is a single vtask (no ring
  partner to hide behind): contention must still couple through the
  simulated-CPU queue, and without ``cpu_resource`` it must be a
  no-op on the victim's timing.
* ``FailHost`` overlapping other failures: an explicit ``FailTask``
  wins over a FailHost expansion regardless of declaration order, a
  second FailHost on an already-failed host keeps the earliest death,
  and two explicit FailTasks on one program stay an error.
"""
import pytest

from engine_harness import assert_engines_agree
from repro.sim import (BitFlip, ClockSkew, DegradeLink, FailHost,
                       FailTask, Interference, RackRing, Scenario,
                       Simulation, Straggler, Topology, Workload)
from repro.sim.topology import FabricSpec
from repro.sim.workload import EndpointSpec, Program
from repro.core.ipc import LinkSpec
from repro.core.vtask import Compute, LiveCall

CROSS_LAT = 50_000      # Topology.racks default cross-rack latency


def _rack(scenario, n_iters=24):
    wl = RackRing(n_iters=n_iters, cross_every=4,
                  skew_bound_ns=2_000_000)
    return Simulation(Topology.racks(2, 2), wl, scenario,
                      placement=wl.default_placement())


# -- DegradeLink exactly at a window boundary ---------------------------------


@pytest.mark.parametrize("from_vtime", [
    CROSS_LAT,            # exactly one cross-rack lookahead window
    3 * CROSS_LAT,        # a later window boundary mid-run
    CROSS_LAT - 1,        # straddling: one below
    CROSS_LAT + 1,        # straddling: one above
], ids=["at_window", "at_3rd_window", "one_below", "one_above"])
def test_degrade_link_at_window_boundary(from_vtime):
    reports = assert_engines_agree(
        lambda: _rack(Scenario(
            "boundary degrade",
            (DegradeLink(hosts=(0, 2), latency_factor=8.0,
                         from_vtime=from_vtime),))),
        label=f"from_vtime={from_vtime}")
    healthy = assert_engines_agree(lambda: _rack(Scenario()))
    rep, base = reports["async"], healthy["async"]
    assert rep.status == base.status == "ok"
    assert rep.messages == base.messages     # only latency, never loss
    assert rep.vtime_ns > base.vtime_ns      # the slow link really bit


def test_degrade_from_vtime_is_inclusive():
    """A message sent exactly at ``from_vtime`` is charged (send_vtime
    >= from_vtime), pinning the boundary semantics."""
    sim = _rack(Scenario(
        "degrade from 0",
        (DegradeLink(hosts=(0, 2), extra_ns=123_456, from_vtime=0),)))
    degraded = sim.run(on_deadlock="raise")
    baseline = _rack(Scenario()).run(on_deadlock="raise")
    assert degraded.vtime_ns > baseline.vtime_ns


# -- Interference on a host with a single vtask -------------------------------


class _Solo(Workload):
    """One program, one endpoint, no communication."""

    name = "solo"

    def __init__(self, n_bursts=10, burst_ns=10_000):
        self.n_bursts = n_bursts
        self.burst_ns = burst_ns

    def programs(self):
        def make_body(eps):
            def body():
                for _ in range(self.n_bursts):
                    yield Compute(self.burst_ns)
            return body()
        return [Program(name="solo0", make_body=make_body,
                        endpoints=(EndpointSpec("solo0.ep", "lone"),))]

    def fabrics(self):
        return [FabricSpec("lone", LinkSpec())]


def test_interference_on_single_vtask_host():
    alone = Simulation(Topology.single_host(n_cpus=1), _Solo(),
                       cpu_resource=True).run(on_deadlock="raise")
    noisy = Simulation(
        Topology.single_host(n_cpus=1), _Solo(),
        Scenario("noisy", (Interference(co_locate_with="solo0",
                                        bursts=10, burst_ns=10_000),)),
        cpu_resource=True).run(on_deadlock="raise")
    assert alone.tasks["solo0"]["vtime"] == 100_000
    # the victim has no peers to absorb slack: contention for the one
    # simulated CPU must surface directly in its final vtime
    assert noisy.tasks["solo0"]["vtime"] > alone.tasks["solo0"]["vtime"]
    assert noisy.status == "ok"
    # and every engine prices the contention identically
    assert_engines_agree(
        lambda: Simulation(
            Topology.single_host(n_cpus=1), _Solo(),
            Scenario("noisy", (Interference(host=0, bursts=10,
                                            burst_ns=10_000),)),
            cpu_resource=True),
        label="solo interference")


def test_interference_without_cpu_resource_is_inert():
    """Without cpu_resource the load runs on uncontended virtual CPUs:
    the victim's timing must be untouched."""
    alone = Simulation(Topology.single_host(n_cpus=1),
                       _Solo()).run(on_deadlock="raise")
    noisy = Simulation(
        Topology.single_host(n_cpus=1), _Solo(),
        Scenario("noisy", (Interference(co_locate_with="solo0",
                                        bursts=10, burst_ns=10_000),)),
    ).run(on_deadlock="raise")
    assert noisy.tasks["solo0"]["vtime"] == alone.tasks["solo0"]["vtime"]


# -- FailHost of an already-failed host ---------------------------------------


def _fail_sim(*injections):
    wl = RackRing(n_iters=20, skew_bound_ns=2_000_000)
    return Simulation(Topology.racks(2, 2), wl,
                      Scenario("fails", tuple(injections)),
                      placement=wl.default_placement())


def test_failhost_twice_keeps_earliest_death():
    twice = _fail_sim(FailHost(host=3, at_vtime=60_000),
                      FailHost(host=3, at_vtime=10_000)).run()
    once = _fail_sim(FailHost(host=3, at_vtime=10_000)).run()
    assert twice.tasks == once.tasks
    assert twice.status == once.status == "deadlock"


@pytest.mark.parametrize("order", ["task_first", "host_first"])
def test_explicit_failtask_wins_over_failhost_expansion(order):
    task = FailTask("w3", at_vtime=10_000)
    host = FailHost(host=3, at_vtime=60_000)
    injections = (task, host) if order == "task_first" else (host, task)
    rep = _fail_sim(*injections).run()
    explicit_only = _fail_sim(task).run()
    assert rep.tasks == explicit_only.tasks


def test_two_explicit_failtasks_still_error():
    with pytest.raises(ValueError, match="two failures"):
        _fail_sim(FailTask("w3", at_vtime=10_000),
                  FailTask("w3", at_vtime=20_000)).build()


def test_failhost_on_already_wedged_host_agrees_across_engines():
    """Host 3 dies early, then 'dies again' later: every engine must
    report the identical wedged state."""
    assert_engines_agree(
        lambda: _fail_sim(FailHost(host=3, at_vtime=10_000),
                          FailHost(host=3, at_vtime=60_000),
                          Straggler("w1", 2.0)),
        label="double host death")


# -- build-time rejection of nonexistent / invalid targets --------------------


@pytest.mark.parametrize("inj,msg", [
    (Straggler("nope", 2.0), r"unknown programs.*available.*w0"),
    (FailTask("nope", at_vtime=0), r"unknown programs.*available.*w0"),
    (FailHost(host=9, at_vtime=0), r"FailHost host 9 outside 0\.\.3"),
    (DegradeLink(hosts=(0, 9)), r"DegradeLink hosts \(0, 9\) outside"),
    (DegradeLink(fabric="nope"), r"unknown fabric 'nope'"),
    (BitFlip("nope", at_step=0), r"unknown program 'nope'.*available"),
    (BitFlip("w0", at_step=0, at_vtime=5), r"exactly one of"),
    (BitFlip("w0"), r"exactly one of"),
    (BitFlip("w0", at_step=0, bit=-1), r"bit must be >= 0"),
    (ClockSkew(host=9), r"ClockSkew host 9 outside 0\.\.3"),
    (ClockSkew(host=0, offset_ns=-5), r"may only delay"),
    (ClockSkew(host=0, drift_ppm=-1), r"may only delay"),
], ids=lambda v: getattr(type(v), "__name__", str(v))[:24])
def test_injections_reject_bad_targets_at_build_time(inj, msg):
    """Every injection type must refuse a target that does not exist
    (or a trigger that cannot fire) when the simulation is *built* —
    a typo'd fault plan silently no-opping would make a whole campaign
    sweep vacuous."""
    wl = RackRing(n_iters=4, skew_bound_ns=100_000)
    sim = Simulation(Topology.racks(2, 2), wl,
                     Scenario("bad", (inj,)),
                     placement=wl.default_placement())
    with pytest.raises(ValueError, match=msg):
        sim.run()


# -- BitFlip observability on a LiveCall result -------------------------------


class _LiveProbe(Workload):
    """One live program that *uses* its LiveCall result for downstream
    timing: a flipped result must visibly change the simulation."""

    name = "probe"

    def programs(self):
        def make_body(eps):
            def body():
                r = yield LiveCall(lambda: 7, cost_ns=100)
                yield Compute((r % 16) * 1_000)
            return body()
        return [Program(name="probe0", make_body=make_body,
                        kind="live",
                        endpoints=(EndpointSpec("probe0.ep", "p"),))]

    def fabrics(self):
        return [FabricSpec("p", LinkSpec())]


def test_bitflip_on_livecall_result_is_observable_downstream():
    def probe(*inj):
        return lambda: Simulation(Topology.single_host(n_cpus=1),
                                  _LiveProbe(),
                                  Scenario("probe", tuple(inj)))

    clean = probe()().run()
    # bit 1: the live step's 7 becomes 5 -> 2us less downstream compute
    flipped = assert_engines_agree(
        probe(BitFlip("probe0", at_step=0, bit=1)),
        label="livecall flip")["single"]
    assert clean.tasks["probe0"]["vtime"] == 100 + 7_000
    assert flipped.tasks["probe0"]["vtime"] == 100 + 5_000
