"""Scenario-injection edge cases the main suites leave uncovered.

* ``DegradeLink.from_vtime`` landing exactly on a synchronization
  window boundary (the cross-rack lookahead and its multiples) — the
  >=-vs-> boundary must bind identically under every engine, or a
  degraded message could be charged in one engine and not another.
* ``Interference`` on a host whose victim is a single vtask (no ring
  partner to hide behind): contention must still couple through the
  simulated-CPU queue, and without ``cpu_resource`` it must be a
  no-op on the victim's timing.
* ``FailHost`` overlapping other failures: an explicit ``FailTask``
  wins over a FailHost expansion regardless of declaration order, a
  second FailHost on an already-failed host keeps the earliest death,
  and two explicit FailTasks on one program stay an error.
"""
import pytest

from engine_harness import assert_engines_agree
from repro.sim import (DegradeLink, FailHost, FailTask, Interference,
                       RackRing, Scenario, Simulation, Straggler,
                       Topology, Workload)
from repro.sim.topology import FabricSpec
from repro.sim.workload import EndpointSpec, Program
from repro.core.ipc import LinkSpec
from repro.core.vtask import Compute

CROSS_LAT = 50_000      # Topology.racks default cross-rack latency


def _rack(scenario, n_iters=24):
    wl = RackRing(n_iters=n_iters, cross_every=4,
                  skew_bound_ns=2_000_000)
    return Simulation(Topology.racks(2, 2), wl, scenario,
                      placement=wl.default_placement())


# -- DegradeLink exactly at a window boundary ---------------------------------


@pytest.mark.parametrize("from_vtime", [
    CROSS_LAT,            # exactly one cross-rack lookahead window
    3 * CROSS_LAT,        # a later window boundary mid-run
    CROSS_LAT - 1,        # straddling: one below
    CROSS_LAT + 1,        # straddling: one above
], ids=["at_window", "at_3rd_window", "one_below", "one_above"])
def test_degrade_link_at_window_boundary(from_vtime):
    reports = assert_engines_agree(
        lambda: _rack(Scenario(
            "boundary degrade",
            (DegradeLink(hosts=(0, 2), latency_factor=8.0,
                         from_vtime=from_vtime),))),
        label=f"from_vtime={from_vtime}")
    healthy = assert_engines_agree(lambda: _rack(Scenario()))
    rep, base = reports["async"], healthy["async"]
    assert rep.status == base.status == "ok"
    assert rep.messages == base.messages     # only latency, never loss
    assert rep.vtime_ns > base.vtime_ns      # the slow link really bit


def test_degrade_from_vtime_is_inclusive():
    """A message sent exactly at ``from_vtime`` is charged (send_vtime
    >= from_vtime), pinning the boundary semantics."""
    sim = _rack(Scenario(
        "degrade from 0",
        (DegradeLink(hosts=(0, 2), extra_ns=123_456, from_vtime=0),)))
    degraded = sim.run(on_deadlock="raise")
    baseline = _rack(Scenario()).run(on_deadlock="raise")
    assert degraded.vtime_ns > baseline.vtime_ns


# -- Interference on a host with a single vtask -------------------------------


class _Solo(Workload):
    """One program, one endpoint, no communication."""

    name = "solo"

    def __init__(self, n_bursts=10, burst_ns=10_000):
        self.n_bursts = n_bursts
        self.burst_ns = burst_ns

    def programs(self):
        def make_body(eps):
            def body():
                for _ in range(self.n_bursts):
                    yield Compute(self.burst_ns)
            return body()
        return [Program(name="solo0", make_body=make_body,
                        endpoints=(EndpointSpec("solo0.ep", "lone"),))]

    def fabrics(self):
        return [FabricSpec("lone", LinkSpec())]


def test_interference_on_single_vtask_host():
    alone = Simulation(Topology.single_host(n_cpus=1), _Solo(),
                       cpu_resource=True).run(on_deadlock="raise")
    noisy = Simulation(
        Topology.single_host(n_cpus=1), _Solo(),
        Scenario("noisy", (Interference(co_locate_with="solo0",
                                        bursts=10, burst_ns=10_000),)),
        cpu_resource=True).run(on_deadlock="raise")
    assert alone.tasks["solo0"]["vtime"] == 100_000
    # the victim has no peers to absorb slack: contention for the one
    # simulated CPU must surface directly in its final vtime
    assert noisy.tasks["solo0"]["vtime"] > alone.tasks["solo0"]["vtime"]
    assert noisy.status == "ok"
    # and every engine prices the contention identically
    assert_engines_agree(
        lambda: Simulation(
            Topology.single_host(n_cpus=1), _Solo(),
            Scenario("noisy", (Interference(host=0, bursts=10,
                                            burst_ns=10_000),)),
            cpu_resource=True),
        label="solo interference")


def test_interference_without_cpu_resource_is_inert():
    """Without cpu_resource the load runs on uncontended virtual CPUs:
    the victim's timing must be untouched."""
    alone = Simulation(Topology.single_host(n_cpus=1),
                       _Solo()).run(on_deadlock="raise")
    noisy = Simulation(
        Topology.single_host(n_cpus=1), _Solo(),
        Scenario("noisy", (Interference(co_locate_with="solo0",
                                        bursts=10, burst_ns=10_000),)),
    ).run(on_deadlock="raise")
    assert noisy.tasks["solo0"]["vtime"] == alone.tasks["solo0"]["vtime"]


# -- FailHost of an already-failed host ---------------------------------------


def _fail_sim(*injections):
    wl = RackRing(n_iters=20, skew_bound_ns=2_000_000)
    return Simulation(Topology.racks(2, 2), wl,
                      Scenario("fails", tuple(injections)),
                      placement=wl.default_placement())


def test_failhost_twice_keeps_earliest_death():
    twice = _fail_sim(FailHost(host=3, at_vtime=60_000),
                      FailHost(host=3, at_vtime=10_000)).run()
    once = _fail_sim(FailHost(host=3, at_vtime=10_000)).run()
    assert twice.tasks == once.tasks
    assert twice.status == once.status == "deadlock"


@pytest.mark.parametrize("order", ["task_first", "host_first"])
def test_explicit_failtask_wins_over_failhost_expansion(order):
    task = FailTask("w3", at_vtime=10_000)
    host = FailHost(host=3, at_vtime=60_000)
    injections = (task, host) if order == "task_first" else (host, task)
    rep = _fail_sim(*injections).run()
    explicit_only = _fail_sim(task).run()
    assert rep.tasks == explicit_only.tasks


def test_two_explicit_failtasks_still_error():
    with pytest.raises(ValueError, match="two failures"):
        _fail_sim(FailTask("w3", at_vtime=10_000),
                  FailTask("w3", at_vtime=20_000)).build()


def test_failhost_on_already_wedged_host_agrees_across_engines():
    """Host 3 dies early, then 'dies again' later: every engine must
    report the identical wedged state."""
    assert_engines_agree(
        lambda: _fail_sim(FailHost(host=3, at_vtime=10_000),
                          FailHost(host=3, at_vtime=60_000),
                          Straggler("w1", 2.0)),
        label="double host death")
