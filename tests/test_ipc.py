"""Simulation-aware IPC semantics (paper §3.4)."""
import pytest

from repro.core import (Compute, Endpoint, Hub, LinkSpec, Message, Recv,
                        Scheduler, Scope, Send, State, US, MS, SEC, VTask)


def test_visibility_time_serialization_and_latency():
    hub = Hub("h", LinkSpec(bandwidth_bps=8e9, latency_ns=5_000))  # 1 GB/s
    rx = hub.attach(Endpoint("rx"))
    hub.attach(Endpoint("tx"))
    msg = hub.send("tx", "rx", size_bytes=1_000_000, send_vtime=0)
    # 1 MB at 1 GB/s = 1 ms serialization + 5 us latency
    assert msg.visibility_time == pytest.approx(1 * MS + 5 * US, rel=1e-6)
    assert rx.pending() == 1


def test_fifo_link_queuing():
    hub = Hub("h", LinkSpec(bandwidth_bps=8e9, latency_ns=0))
    hub.attach(Endpoint("rx"))
    hub.attach(Endpoint("tx"))
    m1 = hub.send("tx", "rx", 1_000_000, send_vtime=0)
    m2 = hub.send("tx", "rx", 1_000_000, send_vtime=0)   # queued behind m1
    assert m2.visibility_time == 2 * m1.visibility_time
    assert hub.stats["queued_ns"] == m1.visibility_time


def test_visibility_ordering_at_receiver():
    """Messages become visible in virtual-time order, not send order."""
    hub = Hub("h")
    rx = hub.attach(Endpoint("rx"))
    hub.attach(Endpoint("a"))
    hub.attach(Endpoint("b"))
    hub.connect("a", "rx", LinkSpec(bandwidth_bps=8e9, latency_ns=500_000))
    hub.connect("b", "rx", LinkSpec(bandwidth_bps=8e9, latency_ns=1_000))
    first = hub.send("a", "rx", 100, send_vtime=0)        # slow link
    second = hub.send("b", "rx", 100, send_vtime=10_000)  # fast link
    assert second.visibility_time < first.visibility_time
    got = rx.pop_visible(vtime=second.visibility_time)
    assert got is second
    assert rx.pop_visible(vtime=second.visibility_time) is None  # not yet
    assert rx.pop_visible(vtime=first.visibility_time) is first


def test_receiver_cannot_see_future_messages():
    """Causality: a receiver at vtime t must not observe a message with
    visibility > t (the scheduler idles it forward instead)."""
    hub = Hub("h", LinkSpec(bandwidth_bps=8e9, latency_ns=100 * US))
    sched = Scheduler(n_cpus=2)
    rx_ep = hub.attach(Endpoint("rx"))
    tx_ep = hub.attach(Endpoint("tx"))
    seen = []

    def sender():
        yield Compute(50 * US)
        yield Send(tx_ep, "rx", 1000)

    def receiver():
        msg = yield Recv(rx_ep)
        seen.append(("vtime", msg.visibility_time))

    tx = sched.spawn(VTask("tx", sender(), kind="modeled"))
    rx = sched.spawn(VTask("rx", receiver(), kind="modeled"))
    sched.run()
    assert rx.state == State.DONE
    # receiver's vtime advanced to at least the visibility time
    assert rx.vtime >= seen[0][1]
    assert rx.vtime >= 150 * US


def test_ebpf_hook_adds_latency_inline():
    hub = Hub("h", LinkSpec(bandwidth_bps=8e9, latency_ns=0))
    hub.attach(Endpoint("rx"))
    hub.attach(Endpoint("tx"))

    def prio_hook(msg: Message, state: dict) -> int:
        state.setdefault("count", 0)
        state["count"] += 1
        return 7_000 if msg.size_bytes > 500 else 0

    hub.add_hook(prio_hook)
    small = hub.send("tx", "rx", 100, send_vtime=0)
    big = hub.send("tx", "rx", 1000, send_vtime=0)
    assert hub.state["count"] == 2
    assert big.visibility_time - big.send_vtime >= 7_000
    assert small.visibility_time - small.send_vtime < 7_000


def test_distributed_hub_cross_host_routing():
    """One logical hub as two distributed instances (paper §3.5)."""
    dcn = LinkSpec(bandwidth_bps=25e9 * 8, latency_ns=10_000)
    h0 = Hub("h0", LinkSpec(bandwidth_bps=80e9 * 8, latency_ns=1_000))
    h1 = Hub("h1", LinkSpec(bandwidth_bps=80e9 * 8, latency_ns=1_000))
    h0.peer_with(h1, dcn)
    hub0_a = h0.attach(Endpoint("a"))
    h1.attach(Endpoint("b"))
    msg = h0.send("a", "b", 1_000_000, send_vtime=0)
    # crossed the DCN: at least the DCN serialization + both latencies
    assert msg.visibility_time >= 10_000
    assert msg.hops == 2
    assert h1.endpoints["b"].pending() == 1


def test_pingpong_end_to_end_vtime():
    """Request/response through a hub accumulates exact link latency."""
    lat = 25 * US
    hub = Hub("h", LinkSpec(bandwidth_bps=1e12 * 8, latency_ns=lat))
    sched = Scheduler(n_cpus=2, send_overhead_ns=0)
    cl = hub.attach(Endpoint("client"))
    sv = hub.attach(Endpoint("server"))
    n = 10

    def client():
        for _ in range(n):
            yield Send(cl, "server", 64)
            yield Recv(cl)

    def server():
        for _ in range(n):
            msg = yield Recv(sv)
            yield Send(sv, "client", 64)

    c = sched.spawn(VTask("c", client(), kind="modeled"))
    s = sched.spawn(VTask("s", server(), kind="modeled"))
    sched.run()
    assert c.state == State.DONE and s.state == State.DONE
    # n round trips x 2 hops x latency (serialization ~ 0 at 1 TB/s)
    assert c.vtime == pytest.approx(n * 2 * lat, rel=0.01)
