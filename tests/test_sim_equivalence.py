"""Facade <-> direct-construction and engine <-> engine equivalence.

Two bars, both bit-exact:

* ``build_training_cluster`` and ``build_rack_cluster`` are thin
  adapters over `repro.sim`; these tests hand-wire the same simulations
  exactly the way the pre-facade builders did (Scheduler/Hub/Endpoint/
  VTask plumbing, straggler/failure logic folded into the bodies) and
  require bit-identical results: final vtimes, message counts, and
  progress arrays.
* every facade scenario must produce identical results under every
  orchestration engine — single, barrier, async, and the multi-process
  dist engine with 1 and K OS workers — via the shared
  ``tests/engine_harness.py`` (which replaced the hand-rolled pairwise
  mode comparisons this file used to carry).
"""
import numpy as np
import pytest

from engine_harness import assert_engines_agree
from repro.core.cluster import (ClusterSpec, StepCost, StragglerSpec,
                                build_rack_cluster,
                                build_training_cluster)
from repro.core.ipc import Endpoint, Hub, LinkSpec
from repro.core.scheduler import Scheduler
from repro.core.scope import Scope
from repro.core.vtask import Compute, Recv, Send, State, VTask
from repro.sim import (BitFlip, ChipRingTraining, ClockSkew,
                       DegradeLink, FailHost, Interference,
                       ModeledServe, RackRing, Scenario, Simulation,
                       Straggler, Topology)

SPEC = ClusterSpec(n_pods=2, chips_per_pod=4)
COST = StepCost(compute_ns=50_000, ici_bytes=100_000, dcn_bytes=10_000)


# -- direct constructions: verbatim ports of the pre-facade builders ---------


def direct_training(spec, step_cost, n_steps, *, skew_bound_ns=1_000_000,
                    stragglers=(), fail_at=None):
    sched = Scheduler(n_cpus=64)
    pod_hubs = [Hub(f"ici{p}", LinkSpec(bandwidth_bps=spec.ici_bw_Bps * 8,
                                        latency_ns=spec.ici_lat_ns))
                for p in range(spec.n_pods)]
    dcn = Hub("dcn", LinkSpec(bandwidth_bps=spec.dcn_bw_Bps * 8,
                              latency_ns=spec.dcn_lat_ns))
    scope = Scope("train", skew_bound_ns)
    slowdown = {s.chip: s.slowdown for s in stragglers}

    endpoints = []
    dcn_eps = []
    for c in range(spec.n_chips):
        p = c // spec.chips_per_pod
        ep = pod_hubs[p].attach(Endpoint(f"chip{c}"))
        endpoints.append(ep)
        if c % spec.chips_per_pod == 0:
            dcn_eps.append(dcn.attach(Endpoint(f"pod{p}")))

    tasks = []
    done_steps = np.zeros(spec.n_chips, dtype=np.int64)

    def chip_body(c):
        p = c // spec.chips_per_pod
        right = p * spec.chips_per_pod + (c + 1) % spec.chips_per_pod
        ep = endpoints[c]
        mult = slowdown.get(c, 1.0)

        def body():
            for step in range(n_steps):
                if fail_at is not None and fail_at == (c, step):
                    return
                yield Compute(int(step_cost.compute_ns * mult))
                yield Send(ep, f"chip{right}", step_cost.ici_bytes)
                yield Recv(ep)
                if spec.n_pods > 1 and c % spec.chips_per_pod == 0:
                    other = (p + 1) % spec.n_pods
                    yield Send(dcn_eps[p], f"pod{other}",
                               step_cost.dcn_bytes)
                    yield Recv(dcn_eps[p])
                done_steps[c] = step + 1

        t = VTask(f"chip{c}", body(), kind="modeled")
        t.join(scope)
        return t

    for c in range(spec.n_chips):
        tasks.append(sched.spawn(chip_body(c)))
    return sched, tasks, pod_hubs + [dcn], done_steps


def direct_rack(*, n_racks=2, hosts_per_rack=2, n_iters=200,
                compute_ns=5_000, msg_bytes=4096, cross_every=20,
                intra_link=LinkSpec(bandwidth_bps=80e9 * 8,
                                    latency_ns=2_000),
                cross_link=LinkSpec(bandwidth_bps=25e9 * 8,
                                    latency_ns=50_000),
                rack_slowdown=(), skew_bound_ns=0, mode="async"):
    from repro.core.orchestrator import Orchestrator

    n_hosts = n_racks * hosts_per_rack
    orch = Orchestrator(n_hosts=n_hosts, n_cpus=4, mode=mode)
    for a in range(n_hosts):
        for b in range(a + 1, n_hosts):
            same_rack = a // hosts_per_rack == b // hosts_per_rack
            orch.connect_hosts(a, b,
                               intra_link if same_rack else cross_link)
    hubs = [orch.add_hub(h, Hub(f"hub{h}",
                                LinkSpec(bandwidth_bps=80e9 * 8,
                                         latency_ns=500)))
            for h in range(n_hosts)]
    eps = [hubs[h].attach(Endpoint(f"w{h}")) for h in range(n_hosts)]
    xeps = {r: hubs[r * hosts_per_rack].attach(Endpoint(f"lead{r}"))
            for r in range(n_racks)}
    iters_done = np.zeros(n_hosts, dtype=np.int64)

    def worker(h):
        r = h // hosts_per_rack
        slot = h % hosts_per_rack
        right = r * hosts_per_rack + (slot + 1) % hosts_per_rack
        mult = rack_slowdown[r] if r < len(rack_slowdown) else 1.0
        is_leader = slot == 0
        next_rack = (r + 1) % n_racks

        def body():
            for i in range(n_iters):
                yield Compute(int(compute_ns * mult))
                if hosts_per_rack > 1:
                    yield Send(eps[h], f"w{right}", msg_bytes)
                    yield Recv(eps[h])
                if (is_leader and n_racks > 1
                        and (i + 1) % cross_every == 0):
                    yield Send(xeps[r], f"lead{next_rack}", msg_bytes)
                    yield Recv(xeps[r])
                iters_done[h] = i + 1

        return orch.host(h).spawn(VTask(f"w{h}", body(), kind="modeled"))

    tasks = [worker(h) for h in range(n_hosts)]
    if skew_bound_ns > 0:
        orch.global_scope("cluster", tasks, skew_bound_ns=skew_bound_ns)
    return orch, tasks, hubs, iters_done


# -- training: facade adapter == direct wiring --------------------------------


@pytest.mark.parametrize("kwargs", [
    dict(),
    dict(stragglers=(StragglerSpec(chip=1, slowdown=2.0),)),
    dict(stragglers=(StragglerSpec(chip=2, slowdown=1.5),
                     StragglerSpec(chip=5, slowdown=3.0),)),
    # duplicate specs for one chip: legacy dict semantics (last wins,
    # no compounding)
    dict(stragglers=(StragglerSpec(chip=1, slowdown=2.0),
                     StragglerSpec(chip=1, slowdown=3.0),)),
], ids=["baseline", "one_straggler", "two_stragglers",
        "duplicate_straggler"])
def test_training_adapter_bit_identical(kwargs):
    d_sched, d_tasks, d_hubs, d_done = direct_training(
        SPEC, COST, 3, skew_bound_ns=200_000, **kwargs)
    d_sched.run()

    f_eng, f_tasks, f_ctx = build_training_cluster(
        SPEC, COST, 3, skew_bound_ns=200_000, **kwargs)
    f_eng.run()

    assert [t.vtime for t in f_tasks] == [t.vtime for t in d_tasks]
    assert [t.state for t in f_tasks] == [t.state for t in d_tasks]
    assert (sum(h.stats["messages"] for h in f_ctx["hubs"])
            == sum(h.stats["messages"] for h in d_hubs))
    assert (f_ctx["done_steps"] == d_done).all()


def test_training_adapter_failure_bit_identical():
    """A chip death wedges the ring identically in both constructions
    (same vtimes at the stall, same partial progress)."""
    d_sched, d_tasks, d_hubs, d_done = direct_training(
        SPEC, COST, 3, skew_bound_ns=200_000, fail_at=(3, 1))
    with pytest.raises(Exception):
        d_sched.run()

    f_eng, f_tasks, f_ctx = build_training_cluster(
        SPEC, COST, 3, skew_bound_ns=200_000, fail_at=(3, 1))
    with pytest.raises(Exception):
        f_eng.run()

    assert [t.vtime for t in f_tasks] == [t.vtime for t in d_tasks]
    assert (f_ctx["done_steps"] == d_done).all()
    assert d_done.min() < 3        # the failure really cut progress short


# -- rack: facade adapter == direct wiring, both engines ----------------------


@pytest.mark.parametrize("mode", ["async", "barrier"])
def test_rack_adapter_bit_identical(mode):
    kw = dict(n_iters=60, rack_slowdown=(1.0, 3.0),
              skew_bound_ns=2_000_000, mode=mode)
    d_orch, d_tasks, d_hubs, d_done = direct_rack(**kw)
    d_res = d_orch.run()

    f_orch, f_tasks, f_ctx = build_rack_cluster(**kw)
    f_res = f_orch.run()

    assert all(t.state == State.DONE for t in f_tasks)
    assert [t.vtime for t in f_tasks] == [t.vtime for t in d_tasks]
    assert f_res["messages"] == d_res["messages"]
    assert (f_ctx["iters_done"] == d_done).all()


def test_rack_adapter_mode_equivalence(engine_harness):
    """The rack workload agrees bit-exactly under every engine —
    barrier, async, and dist across OS processes — and async needs
    fewer synchronization rounds than barrier."""
    def make():
        wl = RackRing(n_racks=2, hosts_per_rack=2, n_iters=60,
                      skew_bound_ns=2_000_000)
        return Simulation(
            Topology.racks(2, 2), wl,
            Scenario("imbalanced racks", wl.stragglers((1.0, 3.0))),
            placement=wl.default_placement())

    reports = engine_harness(make)
    assert reports["async"].status == "ok"
    assert reports["async"].sync_rounds < reports["barrier"].sync_rounds


def test_sharded_training_links_follow_actual_placement():
    """DCN-heavy traffic makes co_locate merge pod leaders across pods;
    host-pair link classes must follow where chips actually landed, not
    an assumed contiguous sharding."""
    heavy_dcn = StepCost(compute_ns=50_000, ici_bytes=10_000,
                         dcn_bytes=100_000)
    eng, tasks, ctx = build_training_cluster(
        SPEC, heavy_dcn, 2, skew_bound_ns=200_000, chips_per_host=4)
    sim = ctx["sim"]
    pod = {f"chip{c}": c // SPEC.chips_per_pod
           for c in range(SPEC.n_chips)}
    host_pods = {}
    for name, h in sim.placement.items():
        host_pods.setdefault(h, set()).add(pod[name])
    for (a, b), link in sim.topology.host_links.items():
        shared = host_pods.get(a, set()) & host_pods.get(b, set())
        expected = SPEC.ici_lat_ns if shared else SPEC.dcn_lat_ns
        assert link.latency_ns == expected, (a, b, host_pods)
    eng.run()
    assert all(t.state == State.DONE for t in tasks)
    assert (ctx["done_steps"] == 2).all()


def test_sharded_training_mode_equivalence(engine_harness):
    """Chips sharded across orchestrated hosts (auto placement on the
    workload traffic matrix): every engine agrees bit-exactly,
    including dist with the ring split across 2 OS worker processes."""
    def make():
        wl = ChipRingTraining(SPEC, COST, 3, skew_bound_ns=200_000)
        return Simulation(Topology(n_hosts=2, n_cpus=32), wl,
                          capacity=4)

    reports = engine_harness(make)
    rep = reports["async"]
    assert rep.status == "ok"
    assert all(t["state"] == "done" for t in rep.tasks.values())
    assert rep.progress["train"]["done_steps"] == [3] * SPEC.n_chips


# -- every facade scenario under every engine (the dist engine's
# -- correctness bar: bit-identical to async/barrier across processes) --------


def _rack_sim(scenario=None, n_iters=40):
    wl = RackRing(n_iters=n_iters, skew_bound_ns=2_000_000)
    return Simulation(Topology.racks(2, 2), wl,
                      scenario or Scenario(),
                      placement=wl.default_placement())


# -- cell-enabled scenarios (§3.3): cell state is keyed by host, so
# -- single/barrier/async/dist must charge identical interference and
# -- reconditioning costs.  Hosts dispatch serially (n_cpus=1), the
# -- regime in which warm-slot transitions are provably engine-exact
# -- (see repro.core.cells).


def _cells_colocated_sim():
    """Single host, four live ring workers over three cells with warm
    slots scarcer than cells (eviction churn): single + barrier +
    async + dist:1."""
    cells = {"w0": "a", "w1": "b", "w2": "c", "w3": "a"}
    wl = RackRing(n_racks=1, hosts_per_rack=4, n_iters=25,
                  compute_ns=40_000, live=True, cells=cells,
                  skew_bound_ns=2_000_000)
    topo = Topology.single_host(n_cpus=1)
    topo.cell("a", ways=2, working_set_frac=0.7, bw_share=0.3,
              bw_demand=0.6, mem_frac=0.6)
    topo.cell("b", ways=6, working_set_frac=0.5, bw_share=0.4,
              bw_demand=0.5, mem_frac=0.4)
    topo.cell("c", ways=4, working_set_frac=0.6, bw_share=0.3,
              bw_demand=0.4, mem_frac=0.5)
    topo.cell_config(n_warm_slots=2, recondition_ns=20_000)
    return Simulation(topo, wl)


def _cells_sharded_sim():
    """Two racks of two live workers, one rack per host: per-host cell
    state + cross-host leader ring under barrier/async/dist:1/dist:2."""
    cells = {"w0": "hot", "w1": "cold", "w2": "hot", "w3": "cold"}
    wl = RackRing(n_racks=2, hosts_per_rack=2, n_iters=30,
                  compute_ns=30_000, cross_every=5, live=True,
                  cells=cells, skew_bound_ns=2_000_000)
    topo = Topology(n_hosts=2, n_cpus=1)
    topo.cell("hot", ways=3, working_set_frac=0.65, bw_share=0.4,
              bw_demand=0.7, mem_frac=0.6)
    topo.cell("cold", ways=6, working_set_frac=0.4, bw_share=0.5,
              bw_demand=0.45, mem_frac=0.3)
    topo.cell_config(n_warm_slots=1, recondition_ns=30_000)
    return Simulation(topo, wl,
                      placement={"w0": 0, "w1": 0, "w2": 1, "w3": 1})


FACADE_SCENARIOS = {
    "baseline": lambda: _rack_sim(),
    "stragglers": lambda: _rack_sim(
        Scenario("stragglers", (Straggler("w1", 2.0),
                                Straggler("w3", 3.0)))),
    "fail_task_wedge": lambda: _rack_sim(
        Scenario("w2 dies", (FailHost(host=2, at_vtime=60_000),))),
    "degrade_link": lambda: _rack_sim(
        Scenario("slow 0<->2", (DegradeLink(hosts=(0, 2),
                                            latency_factor=8.0,
                                            from_vtime=40_000),))),
    "degrade_fabric": lambda: _rack_sim(
        Scenario("slow hub", (DegradeLink(fabric="hub",
                                          extra_ns=5_000),))),
    "interference": lambda: (lambda wl: Simulation(
        Topology.single_host(n_cpus=1), wl,
        Scenario("noisy", (Interference(co_locate_with="chip0",
                                        bursts=20, burst_ns=50_000),)),
        cpu_resource=True))(
            ChipRingTraining(ClusterSpec(n_pods=1, chips_per_pod=4),
                             StepCost(compute_ns=100_000,
                                      ici_bytes=100_000), 4,
                             skew_bound_ns=2_000_000)),
    "multi_workload": lambda: Simulation(
        Topology.single_host(n_cpus=1),
        [ChipRingTraining(ClusterSpec(n_pods=1, chips_per_pod=4),
                          StepCost(compute_ns=500_000,
                                   ici_bytes=1_000_000), 6,
                          skew_bound_ns=5_000_000),
         ModeledServe(n_clients=2, n_requests=6,
                      service_ns=500_000)],
        cpu_resource=True),
    "cells_colocated": _cells_colocated_sim,
    "cells_sharded": _cells_sharded_sim,
    # SDC: a bit-0 flip of client0's request payload makes the server
    # address its response to client1 — every engine must misroute and
    # then wedge identically (the flip is engine-exact, not modeled)
    "bitflip_serve_redirect": lambda: Simulation(
        Topology.single_host(n_cpus=4),
        ModeledServe(n_clients=2, n_requests=4),
        Scenario("flipped client id",
                 (BitFlip("serve.client0", at_step=1, bit=0),))),
    # receive-clock skew: host 1's hub-ingress deliveries arrive late
    # by a constant plus drift that grows with the wire-arrival vtime
    "clock_skew": lambda: _rack_sim(
        Scenario("host 1 skewed",
                 (ClockSkew(host=1, offset_ns=7_000,
                            drift_ppm=200),))),
}


@pytest.mark.parametrize("name", sorted(FACADE_SCENARIOS))
def test_all_engines_agree_on_facade_scenarios(name, engine_harness):
    engine_harness(FACADE_SCENARIOS[name], label=name)


def test_cell_stats_cross_engine_and_nontrivial(engine_harness):
    """The cell-enabled scenarios must not just agree — they must
    actually exercise the subsystem: spatial interference events,
    warm-slot switches, reconditioning time folded into vtimes, and a
    per-host/per-cell report section identical across every engine
    (including across OS process boundaries)."""
    reports = engine_harness(_cells_sharded_sim, label="cells_sharded")
    rep = reports["async"]
    assert rep.status == "ok"
    assert sorted(rep.cells) == ["0", "1"]
    for host in ("0", "1"):
        snap = rep.cells[host]
        assert snap["interference_events"] > 0
        assert snap["switches"] > 0
        assert snap["recondition_ns"] > 0
        assert sorted(snap["cells"]) == ["cold", "hot"]
        hot = snap["cells"]["hot"]
        assert hot["live_calls"] == 30   # this host's hot worker's iters
        assert hot["max_slowdown_ppm"] > 1_000_000
        assert sum(hot["slowdown_hist"].values()) == hot["live_calls"]
    # dist with real worker processes reports the same section
    # (fork-less platforms have no dist engines in the matrix)
    if "dist:2" in reports:
        assert reports["dist:2"].cells == rep.cells
    # and the reconditioning/interference really landed in vtime:
    # an identical sim with no cells finishes strictly earlier
    def no_cells():
        wl = RackRing(n_racks=2, hosts_per_rack=2, n_iters=30,
                      compute_ns=30_000, cross_every=5, live=True,
                      skew_bound_ns=2_000_000)
        return Simulation(Topology(n_hosts=2, n_cpus=1), wl,
                          placement={"w0": 0, "w1": 0,
                                     "w2": 1, "w3": 1})
    bare = no_cells().run(engine="async")
    assert bare.cells == {}
    assert rep.vtime_ns > bare.vtime_ns
