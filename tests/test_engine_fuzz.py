"""Property-based cross-engine equivalence fuzz.

Random small topologies x link latencies x workload shapes x
straggler/failure injections, all run through the shared engine
harness: ``single``/``barrier``/``async``/``dist`` (1 and K OS worker
processes) must agree bit-exactly on every draw — including draws that
wedge the cluster (a failure mid-ring must deadlock identically
everywhere).  On failure hypothesis shrinks to a minimal divergent
scenario, which is exactly the repro an engine bug needs.

The dist engines are pinned *explicitly* into the matrix (not just
inherited from ``engines_for``'s defaults): the multi-process transport
— envelope replay, binary frames, coalesced rounds, adaptive worker
skipping — is exactly the code a refactor is most likely to break in a
way unit tests miss, so every fuzz draw must exercise it.

The hypothesis-driven draws skip when hypothesis is absent (it is in
requirements-dev.txt but not baked into the runtime image); the
deterministic vectorized sweep at the bottom always runs.
"""
import os

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ImportError:                                 # pragma: no cover
    st = None

from engine_harness import assert_engines_agree, engines_for  # noqa: E402
from repro.core.ipc import LinkSpec  # noqa: E402
from repro.sim import (BitFlip, ClockSkew, DegradeLink,  # noqa: E402
                       FailTask, ModeledServe, RackRing, Scenario,
                       Simulation, Straggler, Topology)

LATENCIES = (500, 2_000, 10_000, 50_000)

if st is not None:
    topologies = st.tuples(
        st.integers(min_value=1, max_value=2),      # n_racks
        st.integers(min_value=1, max_value=2),      # hosts_per_rack
        st.sampled_from(LATENCIES),                 # intra-rack latency
        st.sampled_from(LATENCIES),                 # cross-rack latency
    )

    workloads = st.tuples(
        st.integers(min_value=2, max_value=8),      # n_iters
        st.sampled_from((2_000, 5_000, 20_000)),    # compute_ns
        st.integers(min_value=2, max_value=4),      # cross_every
        st.sampled_from((0, 100_000, 2_000_000)),   # skew_bound_ns
    )


    @st.composite
    def cell_plans(draw, n_workers: int):
        """Optionally bind every worker to a §3.3 cell (live iterations +
        per-host cell state): the engines must then also agree bit-exactly
        on slowdown multipliers, warm-slot switches, and reconditioning
        residues (SimReport.cells is in the harness CORE_FIELDS).

        ``colocate`` stacks two workers per host (serial hosts, n_cpus=1)
        so the multiset actually holds co-active cells — spatial
        interference and warm-slot LRU eviction get fuzzed, not just the
        solo self-pressure path."""
        if not draw(st.booleans()):
            return None
        return {
            "cells": {f"w{w}": f"c{w % 2}" for w in range(n_workers)},
            "colocate": n_workers >= 2 and draw(st.booleans()),
            "specs": (
                dict(ways=draw(st.sampled_from((2, 4))),
                     working_set_frac=0.7, bw_share=0.3,
                     bw_demand=draw(st.sampled_from((0.5, 0.8))),
                     mem_frac=0.5),
                dict(ways=6, working_set_frac=0.4, bw_share=0.5,
                     bw_demand=0.4, mem_frac=0.3),
            ),
            "knobs": dict(n_warm_slots=draw(st.sampled_from((1, 2))),
                          recondition_ns=draw(st.sampled_from((0,
                                                               20_000)))),
        }


    @st.composite
    def scenarios(draw, n_workers: int, vectorizable: bool = False):
        """``vectorizable=True`` restricts draws to the vectorized
        engine's admissible injection surface (no BitFlip/ClockSkew —
        those raise UnsupportedByEngine there by design)."""
        injections = []
        for w in range(n_workers):
            kind = draw(st.sampled_from(("none", "none", "straggler",
                                         "fail")))
            if kind == "straggler":
                injections.append(Straggler(
                    f"w{w}", draw(st.sampled_from((1.5, 2.0, 3.0)))))
            elif kind == "fail":
                injections.append(FailTask(
                    f"w{w}",
                    at_compute=draw(st.integers(min_value=0, max_value=3))))
        if draw(st.booleans()):
            injections.append(DegradeLink(
                fabric="hub",
                extra_ns=draw(st.sampled_from((1_000, 25_000))),
                from_vtime=draw(st.sampled_from((0, 30_000)))))
        if not vectorizable:
            # SDC + skewed-clock draws: the flip gating (step counts,
            # vtime thresholds) and ingress-hook arithmetic must bind
            # identically under every reference/dist engine even when
            # mixed with the modeled fault kinds above
            if draw(st.booleans()):
                w = draw(st.integers(min_value=0,
                                     max_value=n_workers - 1))
                if draw(st.booleans()):
                    injections.append(BitFlip(
                        f"w{w}",
                        at_step=draw(st.integers(min_value=0,
                                                 max_value=3)),
                        bit=draw(st.sampled_from((0, 1, 7)))))
                else:
                    injections.append(BitFlip(
                        f"w{w}",
                        at_vtime=draw(st.sampled_from((0, 10_000,
                                                       50_000))),
                        bit=draw(st.sampled_from((0, 3)))))
            if draw(st.booleans()):
                injections.append(ClockSkew(
                    host=draw(st.integers(min_value=0,
                                          max_value=n_workers - 1)),
                    offset_ns=draw(st.sampled_from((0, 1_000,
                                                    40_000))),
                    drift_ppm=draw(st.sampled_from((0, 50, 500)))))
        return Scenario("fuzz", tuple(injections))


    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(data=st.data())
    def test_random_scenarios_agree_across_engines(data):
        n_racks, per_rack, intra, cross = data.draw(topologies,
                                                    label="topology")
        n_iters, compute_ns, cross_every, skew = data.draw(workloads,
                                                           label="workload")
        n_workers = n_racks * per_rack
        scenario = data.draw(scenarios(n_workers), label="scenario")
        cell_plan = data.draw(cell_plans(n_workers), label="cells")

        def make():
            wl = RackRing(n_racks=n_racks, hosts_per_rack=per_rack,
                          n_iters=n_iters, compute_ns=compute_ns,
                          cross_every=cross_every, skew_bound_ns=skew,
                          live=cell_plan is not None,
                          cells=cell_plan["cells"] if cell_plan else None)
            topo = Topology.racks(
                n_racks, per_rack,
                intra_link=LinkSpec(bandwidth_bps=80e9 * 8,
                                    latency_ns=intra),
                cross_link=LinkSpec(bandwidth_bps=25e9 * 8,
                                    latency_ns=cross),
                # cell state transitions are engine-exact on serial hosts
                n_cpus=1 if cell_plan else 4)
            placement = wl.default_placement()
            if cell_plan:
                for i, spec in enumerate(cell_plan["specs"]):
                    topo.cell(f"c{i}", **spec)
                topo.cell_config(**cell_plan["knobs"])
                if cell_plan["colocate"]:
                    # stack worker pairs: each occupied host's multiset now
                    # holds both cells (co-active interference + LRU churn);
                    # surplus hosts simply idle
                    placement = {f"w{w}": w // 2 for w in range(n_workers)}
            return Simulation(topo, wl, scenario, placement=placement)

        engines = engines_for(n_workers, dist_workers=2)
        if hasattr(os, "fork"):
            # transport refactors must be fuzzed, not just unit-tested:
            # the multi-process engine (1 worker fast path + K-worker
            # coalesced rounds) is required in every draw's matrix
            assert "dist:1" in engines, engines
            assert n_workers == 1 or f"dist:{min(2, n_workers)}" in engines
        assert_engines_agree(make, engines=engines,
                             label=f"{n_racks}x{per_rack} racks")


    # ---------------------------------------------------------------- vectorized


    def _vec_make(n_racks, per_rack, intra, cross, n_iters, compute_ns,
                  cross_every, skew, scenario):
        """Modeled (non-cell) RackRing factory on the admissible surface of
        the vectorized engine."""
        def make():
            wl = RackRing(n_racks=n_racks, hosts_per_rack=per_rack,
                          n_iters=n_iters, compute_ns=compute_ns,
                          cross_every=cross_every, skew_bound_ns=skew)
            topo = Topology.racks(
                n_racks, per_rack,
                intra_link=LinkSpec(bandwidth_bps=80e9 * 8,
                                    latency_ns=intra),
                cross_link=LinkSpec(bandwidth_bps=25e9 * 8,
                                    latency_ns=cross),
                n_cpus=4)
            return Simulation(topo, wl, scenario,
                              placement=wl.default_placement())
        return make


    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(data=st.data())
    def test_random_scenarios_vectorized_exact(data):
        """Exact tier under fuzz: every modeled draw — stragglers, fail
        points, degraded links included — must compile at the auto tick and
        match the async reference bit-exactly (CORE_FIELDS + links)."""
        from engine_harness import assert_vectorized_exact

        n_racks, per_rack, intra, cross = data.draw(topologies,
                                                    label="topology")
        n_iters, compute_ns, cross_every, skew = data.draw(workloads,
                                                           label="workload")
        scenario = data.draw(scenarios(n_racks * per_rack,
                                        vectorizable=True),
                             label="scenario")
        assert_vectorized_exact(
            _vec_make(n_racks, per_rack, intra, cross, n_iters, compute_ns,
                      cross_every, skew, scenario),
            label=f"vec {n_racks}x{per_rack}")


    @settings(max_examples=6, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(data=st.data())
    def test_random_scenarios_vectorized_tolerance(data):
        """Tolerance tier under fuzz: a deliberately coarse explicit tick
        must keep every schedule-independent invariant exact and every
        vtime within the engine's own published bound (tol_ns)."""
        from engine_harness import assert_vectorized_tolerance
        from repro.sim.vectorized import compile_simulation

        n_racks, per_rack, intra, cross = data.draw(topologies,
                                                    label="topology")
        n_iters, compute_ns, cross_every, skew = data.draw(workloads,
                                                           label="workload")
        scenario = data.draw(scenarios(n_racks * per_rack,
                                        vectorizable=True),
                             label="scenario")
        make = _vec_make(n_racks, per_rack, intra, cross, n_iters,
                         compute_ns, cross_every, skew, scenario)
        tol = compile_simulation(make(), tick_ns=100).tol_ns
        assert_vectorized_tolerance(make, 100, vtime_tol_ns=max(tol, 100),
                                    label=f"vec-tol {n_racks}x{per_rack}")


def test_deterministic_sweep_48_draws():
    """One vmap sweep over 48 injection-value draws (fixed topology and
    tapes, so a single compile serves all variants); every lane must
    match a solo async reference run bit-exactly."""
    import numpy as np

    from engine_harness import run_engine
    from repro.sim import FailHost

    rng = np.random.default_rng(7)
    axis = []
    for i in range(48):
        inj = [Straggler(f"w{rng.integers(0, 4)}",
                         float(rng.choice((1.5, 2.0, 2.5, 3.0)))),
               DegradeLink(fabric="hub",
                           extra_ns=int(rng.choice((0, 1_000, 25_000))),
                           from_vtime=int(rng.choice((0, 30_000))))]
        if rng.random() < 0.25:
            inj.append(FailHost(int(rng.integers(0, 4)),
                                at_vtime=int(rng.integers(1, 40) *
                                             10_000)))
        axis.append(Scenario(f"draw{i}", tuple(inj)))

    def base(sc=Scenario("base")):
        wl = RackRing(n_racks=2, hosts_per_rack=2, n_iters=6,
                      compute_ns=5_000, cross_every=2,
                      skew_bound_ns=100_000)
        return Simulation(Topology.racks(2, 2), wl, sc,
                          placement=wl.default_placement())

    res = base().sweep(axis)
    assert len(res.reports) == 48
    for sc, rep in zip(axis, res.reports):
        ref = run_engine(lambda: base(sc), "async")
        assert rep.status == ref.status, sc
        assert rep.vtime_ns == ref.vtime_ns, sc
        assert rep.tasks == ref.tasks, sc
        assert rep.progress == ref.progress, sc


def test_deterministic_bitflip_clockskew_mixed_grids():
    """Always-on (no hypothesis) cross-engine draws for the SDC and
    clock-skew injections, alone and mixed with the modeled kinds: a
    seeded grid of scenarios over the serve and rack bases, each run
    through the full engine matrix.  The bit-0 serve flip corrupts a
    client id, redirecting the server's response — every engine must
    misroute (and then wedge) identically."""
    import numpy as np

    def serve(sc):
        return lambda: Simulation(
            Topology.single_host(n_cpus=4),
            ModeledServe(n_clients=2, n_requests=3), sc)

    def rack(sc):
        def make():
            wl = RackRing(n_racks=2, hosts_per_rack=2, n_iters=6,
                          compute_ns=5_000, cross_every=2,
                          skew_bound_ns=100_000)
            return Simulation(Topology.racks(2, 2), wl, sc,
                              placement=wl.default_placement())
        return make

    rng = np.random.default_rng(11)
    draws = [serve(Scenario("flip0", (BitFlip("serve.client0",
                                              at_step=1, bit=0),)))]
    for i in range(4):
        inj = [ClockSkew(host=int(rng.integers(0, 4)),
                         offset_ns=int(rng.choice((0, 1_000, 40_000))),
                         drift_ppm=int(rng.choice((0, 50, 500))))]
        if rng.random() < 0.5:
            inj.append(Straggler(f"w{rng.integers(0, 4)}", 2.0))
        if rng.random() < 0.5:
            inj.append(BitFlip(f"w{rng.integers(0, 4)}",
                               at_step=int(rng.integers(0, 3)),
                               bit=int(rng.choice((0, 7)))))
        if rng.random() < 0.3:
            inj.append(FailTask(f"w{rng.integers(0, 4)}",
                                at_compute=2))
        draws.append(rack(Scenario(f"mixed{i}", tuple(inj))))
    for make in draws:
        assert_engines_agree(make)


def test_deterministic_membership_churn_grids():
    """Always-on cross-engine draws for membership churn: seeded
    join-vtime grids over the rack base, alone and mixed with
    stragglers and receive-clock skew.  Every engine must admit the
    joiners at the same epoch flip and agree bit-exactly — including
    the ``SimReport.control`` membership timeline, which the harness
    CORE_FIELDS deliberately leave out."""
    import numpy as np

    from repro.sim import JoinHost

    def rack(sc, joins):
        def make():
            topo = Topology.racks(2, 2)
            for h, at in joins:
                topo.join(h, at)
            wl = RackRing(n_racks=2, hosts_per_rack=2, n_iters=8,
                          compute_ns=5_000, cross_every=2,
                          skew_bound_ns=100_000)
            return Simulation(topo, wl, sc,
                              placement=wl.default_placement())
        return make

    rng = np.random.default_rng(23)
    draws = []
    for i in range(5):
        joiners = list(rng.choice((1, 2, 3), size=rng.integers(1, 3),
                                  replace=False))
        vtimes = [int(rng.choice((1, 5_000, 40_000, 200_000)))
                  for _ in joiners]
        inj = []
        if rng.random() < 0.5:
            # half the draws declare joins as injections, half on the
            # topology — both paths must be identical machinery
            inj = [JoinHost(int(h), v) for h, v in zip(joiners, vtimes)]
            joins = ()
        else:
            joins = tuple((int(h), v) for h, v in zip(joiners, vtimes))
        if rng.random() < 0.5:
            inj.append(Straggler(f"w{rng.integers(0, 4)}", 2.0))
        if rng.random() < 0.4:
            stay = [h for h in range(4) if h not in joiners]
            inj.append(ClockSkew(host=int(rng.choice(stay)),
                                 offset_ns=int(rng.choice((0, 1_000))),
                                 drift_ppm=int(rng.choice((0, 50)))))
        draws.append(rack(Scenario(f"churn{i}", tuple(inj)), joins))
    for make in draws:
        reports = assert_engines_agree(make)
        ref = next(iter(reports.values()))
        assert ref.control.get("membership"), "draw produced no churn"
        for rep in reports.values():
            assert rep.control == ref.control
