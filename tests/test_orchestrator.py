"""Distributed simulation orchestration (paper §3.5)."""
import pytest

from repro.core import (Compute, Endpoint, Hub, LinkSpec, Orchestrator,
                        Recv, Scope, Send, State, US, MS, VTask)


def make_hub(lat_ns=1000):
    return Hub("hub", LinkSpec(bandwidth_bps=80e9 * 8, latency_ns=lat_ns))


def test_global_scope_bounded_skew_across_hosts():
    orch = Orchestrator(n_hosts=2, n_cpus=2)
    h0, h1 = orch.host(0), orch.host(1)
    orch.add_hub(0, make_hub())
    orch.add_hub(1, make_hub())

    def worker(step_ns, n):
        def body():
            for _ in range(n):
                yield Compute(step_ns)
        return body

    fast = h0.spawn(VTask("fast", worker(10 * US, 100)(), kind="modeled"))
    slow = h1.spawn(VTask("slow", worker(100 * US, 100)(), kind="modeled"))
    orch.global_scope("g", [fast, slow], skew_bound_ns=50 * US)
    res = orch.run()
    assert fast.state == State.DONE and slow.state == State.DONE
    # epochs were needed (cross-host sync actually happened)
    assert res["epochs"] > 1
    assert orch.stats["proxy_syncs"] > 0


def test_cross_host_messages_preserve_visibility():
    orch = Orchestrator(n_hosts=2, n_cpus=2,
                        dcn_link=LinkSpec(bandwidth_bps=25e9 * 8,
                                          latency_ns=50 * US))
    hub0 = orch.add_hub(0, make_hub())
    hub1 = orch.add_hub(1, make_hub())
    tx_ep = hub0.attach(Endpoint("tx"))
    rx_ep = hub1.attach(Endpoint("rx"))
    got = []

    def sender():
        yield Compute(10 * US)
        yield Send(tx_ep, "rx", 1000)

    def receiver():
        msg = yield Recv(rx_ep)
        got.append(msg)

    s = orch.host(0).spawn(VTask("s", sender(), kind="modeled"))
    r = orch.host(1).spawn(VTask("r", receiver(), kind="modeled"))
    orch.run()
    assert r.state == State.DONE
    assert got[0].hops == 2
    # receiver resumed no earlier than send + DCN latency
    assert r.vtime >= 10 * US + 50 * US


def test_proxy_does_not_pin_when_remote_done():
    orch = Orchestrator(n_hosts=2, n_cpus=1)
    orch.add_hub(0, make_hub())
    orch.add_hub(1, make_hub())

    def quick():
        yield Compute(5 * US)

    def long_run():
        for _ in range(200):
            yield Compute(20 * US)

    q = orch.host(0).spawn(VTask("q", quick(), kind="modeled"))
    l = orch.host(1).spawn(VTask("l", long_run(), kind="modeled"))
    orch.global_scope("g", [q, l], skew_bound_ns=10 * US)
    orch.run()
    # the finished remote task must not deadlock the long runner
    assert l.state == State.DONE
    assert l.vtime == 200 * 20 * US


def test_co_location_reduces_cross_host_traffic():
    comps = [f"c{i}" for i in range(8)]
    traffic = {("c0", "c1"): 100.0, ("c2", "c3"): 90.0,
               ("c4", "c5"): 80.0, ("c6", "c7"): 70.0,
               ("c0", "c4"): 1.0, ("c1", "c6"): 0.5}
    placement = Orchestrator.co_locate(comps, traffic, n_hosts=4,
                                       capacity=2)
    assert placement["c0"] == placement["c1"]
    assert placement["c2"] == placement["c3"]
    assert placement["c4"] == placement["c5"]
    assert placement["c6"] == placement["c7"]
    # balanced across hosts
    from collections import Counter
    assert max(Counter(placement.values()).values()) == 2


def test_co_locate_capacity_one_degenerates_to_balanced_singletons():
    comps = [f"c{i}" for i in range(4)]
    traffic = {("c0", "c1"): 10.0, ("c2", "c3"): 5.0}
    placement = Orchestrator.co_locate(comps, traffic, n_hosts=4,
                                       capacity=1)
    assert sorted(placement) == comps
    # capacity 1 forbids any pair from sharing a host
    from collections import Counter
    assert max(Counter(placement.values()).values()) == 1


def test_co_locate_empty_traffic_balances_components():
    comps = [f"c{i}" for i in range(6)]
    placement = Orchestrator.co_locate(comps, {}, n_hosts=3, capacity=4)
    assert sorted(placement) == comps
    from collections import Counter
    assert max(Counter(placement.values()).values()) == 2


def test_co_locate_more_groups_than_hosts_stacks_on_least_loaded():
    comps = [f"c{i}" for i in range(6)]
    traffic = {("c0", "c1"): 9.0, ("c2", "c3"): 8.0, ("c4", "c5"): 7.0}
    placement = Orchestrator.co_locate(comps, traffic, n_hosts=2,
                                       capacity=2)
    # pairs stay together, every host is used, load split 4/2
    assert placement["c0"] == placement["c1"]
    assert placement["c2"] == placement["c3"]
    assert placement["c4"] == placement["c5"]
    from collections import Counter
    assert sorted(Counter(placement.values()).values()) == [2, 4]


def test_co_locate_ignores_self_edges():
    comps = ["a", "b"]
    traffic = {("a", "a"): 100.0, ("a", "b"): 1.0}
    placement = Orchestrator.co_locate(comps, traffic, n_hosts=2,
                                       capacity=2)
    # "a" must be placed exactly once (no phantom [a, a] group) and the
    # real a<->b edge still co-locates them
    assert sorted(placement) == comps
    assert placement["a"] == placement["b"]


def test_multi_host_pingpong_vtime_accuracy():
    """End-to-end: request/response across hosts accumulates DCN latency."""
    lat = 100 * US
    orch = Orchestrator(n_hosts=2, n_cpus=1,
                        dcn_link=LinkSpec(bandwidth_bps=1e12 * 8,
                                          latency_ns=lat))
    hub0 = orch.add_hub(0, make_hub(lat_ns=0))
    hub1 = orch.add_hub(1, make_hub(lat_ns=0))
    cl = hub0.attach(Endpoint("client"))
    sv = hub1.attach(Endpoint("server"))
    n = 5

    def client():
        for _ in range(n):
            yield Send(cl, "server", 64)
            yield Recv(cl)

    def server():
        for _ in range(n):
            yield Recv(sv)
            yield Send(sv, "client", 64)

    c = orch.host(0).spawn(VTask("c", client(), kind="modeled"))
    s = orch.host(1).spawn(VTask("s", server(), kind="modeled"))
    orch.host(0).send_overhead_ns = 0
    orch.host(1).send_overhead_ns = 0
    orch.run()
    assert c.state == State.DONE and s.state == State.DONE
    assert c.vtime == pytest.approx(n * 2 * lat, rel=0.05)
