"""Live-execution subsystem: record/replay ledger, clock edges, the
cross-engine bar for replayed live scenarios, and the marquee trainer
recovery (replayed from the checked-in golden trace; the real-trainer
record run itself is exercised subprocess-side like the seed's elastic
re-shard test)."""
import json
import pathlib

import pytest

from repro.core import LiveCall, Scheduler, State, VTask
from repro.core.vtime import LiveClock
from repro.live import (TRACE_SCHEMA, CostLedger, LiveTraceError,
                        LiveTraceMismatch)
from repro.sim import (LiveProgram, Scenario, Simulation, Topology,
                       UnsupportedByEngine, live_recovery_sim,
                       recovery_timeline)
from repro.sim.live import check_dist_live

from engine_harness import HAS_FORK, engines_for, run_engine

GOLDEN_TRACE = (pathlib.Path(__file__).parent / "golden"
                / "live_recovery_trace.json")


def work(step):
    return sum(range(200 + step))


# ---------------------------------------------------------------------------
# ledger unit tests
# ---------------------------------------------------------------------------


def test_ledger_record_measures_and_replays_pinned():
    led = CostLedger.record(calibration=3.0)
    r, cost = led.charge("t", "step:0", work, (0,))
    assert r == work(0) and cost >= 1
    led2 = CostLedger.replay(led.to_dict())
    r2, cost2 = led2.charge("t", "step:0")
    assert r2 is None and cost2 == cost


def test_ledger_zero_span_clamped_to_one_ns():
    # calibration tiny enough that any measured span rounds to 0
    led = CostLedger.record(calibration=1e-12)
    _, cost = led.charge("t", "step:0", lambda: None)
    assert cost == 1


def test_ledger_schema_versioned(tmp_path):
    led = CostLedger.record()
    led.charge("t", "step:0", lambda: None)
    path = led.save(tmp_path / "trace.json")
    data = json.loads(path.read_text())
    assert data["schema"] == TRACE_SCHEMA
    data["schema"] = "live_trace/v99"
    with pytest.raises(LiveTraceError, match="v99"):
        CostLedger.replay(data)
    with pytest.raises(LiveTraceError, match="not found"):
        CostLedger.replay(tmp_path / "missing.json")
    bad = tmp_path / "bad.json"
    bad.write_text("{nope")
    with pytest.raises(LiveTraceError, match="not valid JSON"):
        CostLedger.replay(bad)


def test_ledger_mismatch_names_offending_task():
    led = CostLedger.record()
    led.charge("present", "step:0", lambda: None)
    rep = CostLedger.replay(led.to_dict())
    # missing task key names the task the scenario asked for
    with pytest.raises(LiveTraceMismatch, match="'absent'"):
        rep.charge("absent", "step:0")
    # exhaustion and label divergence both name the task
    rep.charge("present", "step:0")
    with pytest.raises(LiveTraceMismatch, match="'present'.*exhausted"):
        rep.charge("present", "step:1")
    rep2 = CostLedger.replay(led.to_dict())
    with pytest.raises(LiveTraceMismatch, match="'present'.*diverged"):
        rep2.charge("present", "step:9")


def test_ledger_rejects_bad_modes_and_saves_record_only(tmp_path):
    with pytest.raises(ValueError, match="record"):
        CostLedger("measure")
    with pytest.raises(ValueError, match="calibration"):
        CostLedger.record(calibration=0.0)
    led = CostLedger.record()
    led.charge("t", "step:0", lambda: None)
    rep = CostLedger.replay(led.to_dict())
    with pytest.raises(LiveTraceError, match="record-mode"):
        rep.save(tmp_path / "x.json")
    with pytest.raises(LiveTraceError, match="corrupt"):
        CostLedger.replay({"schema": TRACE_SCHEMA, "tasks": {
            "t": [{"label": "step:0", "cost_ns": 0}]}}).charge(
                "t", "step:0")


# ---------------------------------------------------------------------------
# LiveCall clock edges (satellite: clamps)
# ---------------------------------------------------------------------------


def test_live_call_cost_zero_rejected_with_message():
    sched = Scheduler(n_cpus=1)

    def body():
        yield LiveCall(lambda: None, cost_ns=0, label="step:0")

    sched.spawn(VTask("bad", body(), kind="live"))
    with pytest.raises(ValueError, match=r"'bad'.*step:0.*>= 1 ns"):
        sched.run()


def test_live_call_zero_measured_span_advances_one_ns():
    sched = Scheduler(n_cpus=1)

    def body():
        yield LiveCall(lambda: None)
        yield LiveCall(lambda: None)

    t = VTask("live", body(), kind="live")
    t.clock = LiveClock(timer=lambda: 0)   # frozen timer: 0-ns spans
    sched.spawn(t)
    sched.run()
    assert t.state == State.DONE
    assert t.vtime == 2                    # >= 1 ns per live call


def test_straggler_never_scales_live_cost_to_zero():
    from repro.sim.scenario import scaled_body

    def body():
        yield LiveCall(lambda: None, cost_ns=5)

    scaled = scaled_body(body(), 0.01)     # 5 * 0.01 -> 0 without clamp
    action = next(scaled)
    assert action.cost_ns == 1


# ---------------------------------------------------------------------------
# record/replay round trip across engines (satellite: bit-identity)
# ---------------------------------------------------------------------------


def _round_trip(n_hosts: int):
    """Record once in-process, then replay under every applicable
    engine and demand the full CORE_FIELDS bar (incl. the live
    section) plus equality with the record run's timings."""
    from engine_harness import assert_reports_equal

    fns = {"a": work, "b": work}
    led = CostLedger.record(calibration=2.0)

    def make(ledger):
        wl = LiveProgram(fns, 3, ledger=ledger, ring_bytes=512)
        if n_hosts == 1:
            return Simulation(Topology.single_host(n_cpus=2), wl)
        return Simulation(Topology.full_mesh(n_hosts, wl.link,
                                             n_cpus=2), wl,
                          placement={"a": 0, "b": 1})

    rec = make(led).run(engine="async")
    assert rec.status == "ok"
    trace = led.to_dict()
    engines = engines_for(n_hosts)
    reports = {eng: run_engine(lambda: make(CostLedger.replay(trace)),
                               eng) for eng in engines}
    base = engines[0]
    for eng in engines[1:]:
        assert_reports_equal(reports[base], reports[eng],
                             label=f"live round-trip {n_hosts}h")
    # replayed vtimes are the recorded vtimes, bit-exactly
    assert reports[base].vtime_ns == rec.vtime_ns
    assert reports[base].tasks == rec.tasks
    assert reports[base].progress == rec.progress
    return reports


def test_round_trip_single_host():
    _round_trip(1)                         # single/barrier/async/dist:1


def test_round_trip_multi_host():
    _round_trip(2)                         # barrier/async/dist:1/dist:2


def test_live_program_unsupported_by_vectorized():
    led = CostLedger.record()
    wl = LiveProgram({"a": work}, 2, ledger=led)
    sim = Simulation(Topology.single_host(n_cpus=2), wl)
    with pytest.raises(UnsupportedByEngine):
        sim.run(engine="vectorized")


# ---------------------------------------------------------------------------
# dist facade guards (satellite: picklability)
# ---------------------------------------------------------------------------


def test_dist_rejects_unpicklable_live_fn_naming_it():
    wl = LiveProgram({"a": lambda step: None}, 2,   # lambdas don't pickle
                     ledger=CostLedger.replay(
                         {"schema": TRACE_SCHEMA, "tasks": {"a": []}}))
    with pytest.raises(ValueError, match=r"'a'.*lambda.*not picklable"):
        check_dist_live([wl])


def test_dist_rejects_record_mode():
    wl = LiveProgram({"a": work}, 2, ledger=CostLedger.record())
    with pytest.raises(ValueError, match="record mode is not supported"):
        check_dist_live([wl])


@pytest.mark.skipif(not HAS_FORK, reason="dist engine needs os.fork")
def test_dist_facade_error_not_worker_crash():
    # through the facade: the error surfaces from Simulation.run, as a
    # ValueError naming the fn — not a DistWorkerError traceback
    led = CostLedger.replay({"schema": TRACE_SCHEMA, "tasks": {
        "a": [{"label": f"step:{i}", "cost_ns": 10} for i in range(2)]}})
    wl = LiveProgram({"a": lambda step: None}, 2, ledger=led)
    sim = Simulation(Topology.single_host(n_cpus=2), wl)
    with pytest.raises(ValueError, match="not picklable"):
        sim.run(engine="dist", n_workers=1)


# ---------------------------------------------------------------------------
# marquee: live trainer recovery (golden trace replay)
# ---------------------------------------------------------------------------


def _replay_recovery():
    return live_recovery_sim(CostLedger.replay(GOLDEN_TRACE))


def test_marquee_recovery_timeline_ordered():
    rep = _replay_recovery().run(engine="async")
    assert rep.status == "ok"
    sec = rep.live["live_train"]
    assert sec["mode"] == "replay"
    tl = recovery_timeline(rep)
    events = [e["event"] for e in tl]
    assert events == ["detect", "restore", "remesh", "resumed"]
    v = {e["event"]: e["vtime"] for e in tl}
    assert v["detect"] < v["restore"] < v["remesh"] <= v["resumed"]
    task = sec["tasks"]["live.trainer"]
    assert task["restarts"] == 1
    meta = CostLedger.replay(GOLDEN_TRACE).meta["recovery"]
    assert task["final_step"] == meta["n_steps"]


def test_marquee_recovery_bit_identical_across_engines(engine_harness):
    reports = engine_harness(_replay_recovery,
                             label="live recovery replay")
    for rep in reports.values():
        assert recovery_timeline(rep), rep.live


def test_marquee_scenario_trace_mismatch_fails_fast():
    # scenario asks for more steps than the trace recorded: the replay
    # must fail fast naming the live task, not drift silently
    sim = live_recovery_sim(CostLedger.replay(GOLDEN_TRACE),
                            n_steps=32)
    with pytest.raises(LiveTraceMismatch, match="'live.trainer'"):
        sim.run(engine="async")


def test_marquee_unsupported_by_vectorized():
    with pytest.raises(UnsupportedByEngine):
        _replay_recovery().run(engine="vectorized")


def test_recovery_sim_rejects_unknown_override():
    with pytest.raises(ValueError, match="unknown recovery parameters"):
        live_recovery_sim(CostLedger.replay(GOLDEN_TRACE), bogus=1)


def test_record_mode_requires_stack():
    from repro.sim import LiveTrainerRecovery
    with pytest.raises(ValueError, match="TrainerStack"):
        LiveTrainerRecovery(ledger=CostLedger.record())


def test_marquee_real_trainer_records_end_to_end(tmp_path):
    """The full record run: real sharded Trainer + FailHost +
    checkpoint re-mesh under engine='async'.  Needs > 1 device, so it
    runs in a subprocess with its own XLA_FLAGS (like the seed's
    elastic re-shard test); the replayed trace must then reproduce the
    recorded vtimes bit-exactly in this process."""
    import os
    import subprocess
    import sys

    out = tmp_path / "trace.json"
    prog = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
from repro.sim.live import record_live_recovery, recovery_timeline
report, ledger = record_live_recovery({str(out)!r}, n_steps=5,
                                      checkpoint_every=2)
assert report.status == "ok", report.detail
tl = recovery_timeline(report)
v = {{e["event"]: e["vtime"] for e in tl}}
assert v["detect"] < v["restore"] < v["remesh"] <= v["resumed"], tl
print("MARQUEE_OK", report.vtime_ns)
"""
    env = {**os.environ, "PYTHONPATH": "src"}
    res = subprocess.run([sys.executable, "-c", prog],
                         cwd=str(pathlib.Path(__file__).parent.parent),
                         env=env, capture_output=True, text=True,
                         timeout=560)
    assert "MARQUEE_OK" in res.stdout, res.stderr[-2000:]
    recorded_vtime = int(res.stdout.split("MARQUEE_OK")[1].split()[0])
    rep = live_recovery_sim(CostLedger.replay(out)).run(engine="async")
    assert rep.status == "ok"
    assert rep.vtime_ns == recorded_vtime
    assert recovery_timeline(rep)
