"""Beyond-paper optimizations must be numerically exact vs baseline."""
import dataclasses
import subprocess
import sys


def test_tp_attention_exactness_subprocess():
    """tp_attention (TP-aligned GQA) == baseline forward, on a real 2x2
    mesh (needs 4 devices -> subprocess with its own XLA_FLAGS)."""
    prog = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.models import registry
from repro.launch.mesh import make_test_mesh
from repro.parallel import ctx as pctx

for arch in ("phi3_medium_14b", "qwen3_4b", "glm4_9b"):
    cfg = dataclasses.replace(configs.get_smoke(arch), dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    params = registry.init(cfg, key)
    base = registry.forward(cfg, params, tokens)
    mesh = make_test_mesh(data=2, model=2)
    cfg_tp = dataclasses.replace(cfg, tp_attention=True)
    with pctx.use_mesh(mesh):
        opt = jax.jit(lambda p, t: registry.forward(cfg_tp, p, t))(
            params, tokens)
    d = np.abs(np.asarray(base) - np.asarray(opt)).max()
    assert d < 1e-4, (arch, d)
print("TP_OK")
"""
    env = {**__import__("os").environ, "PYTHONPATH": "src"}
    res = subprocess.run([sys.executable, "-c", prog], cwd="/root/repo",
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert "TP_OK" in res.stdout, res.stderr[-2000:]
