"""The `repro.sim` facade: declarative topology/placement/workloads/
fault-injection (ISSUE 2 tentpole).

Covers the Simulation builder (engine auto-pick, auto placement through
``Orchestrator.co_locate``), the structured SimReport, and the three
new injection scenarios that only the facade can express:

  1. straggler + mid-run host failure (blast radius as a structured
     deadlock report),
  2. degraded cross-rack link (mid-run latency inflation),
  3. interference-coupled co-located serving + training
     (simulated-CPU contention).
"""
import json

import numpy as np
import pytest

from repro.core.cluster import ClusterSpec, StepCost
from repro.core.ipc import LinkSpec
from repro.core.vtask import State
from repro.sim import (ChipRingTraining, DegradeLink, FailHost, FailTask,
                       Interference, ModeledServe, RackRing, Scenario,
                       Simulation, Straggler, Topology)

SPEC = ClusterSpec(n_pods=1, chips_per_pod=4)
COST = StepCost(compute_ns=50_000, ici_bytes=100_000)


def small_train(**kw):
    return ChipRingTraining(SPEC, COST, 3, skew_bound_ns=500_000, **kw)


# -- simulation builder -------------------------------------------------------


def test_single_host_auto_picks_scheduler():
    sim = Simulation(Topology.single_host(n_cpus=4), small_train())
    report = sim.run()
    assert sim.scheduler is not None and sim.orchestrator is None
    assert report.status == "ok" and report.mode == "single"
    assert report.n_hosts == 1 and report.sync_rounds == 0
    assert all(t.state == State.DONE for t in sim.tasks)
    assert report.progress["train"]["done_steps"] == [3, 3, 3, 3]


def test_multi_host_auto_picks_async_orchestrator():
    ici = LinkSpec(bandwidth_bps=50e9 * 8, latency_ns=1_000)
    sim = Simulation(Topology.full_mesh(2, ici, n_cpus=4), small_train(),
                     capacity=2)
    report = sim.run()
    assert sim.orchestrator is not None and sim.scheduler is None
    assert report.mode == "async"
    assert report.status == "ok" and report.sync_rounds > 0
    assert report.cross_host_msgs > 0
    # per-link visibility slack surfaced (and conservative: never < 0)
    assert report.links
    assert all(st["min_slack_ns"] >= 0 for st in report.links.values())


def test_auto_placement_routes_through_co_locate():
    """Ring traffic + capacity -> contiguous chunks via co_locate."""
    ici = LinkSpec(bandwidth_bps=50e9 * 8, latency_ns=1_000)
    sim = Simulation(Topology.full_mesh(2, ici, n_cpus=4), small_train(),
                     capacity=2).build()
    hosts = [sim.placement[f"chip{c}"] for c in range(4)]
    assert sorted(hosts) == [0, 0, 1, 1]
    # ring neighbors co-locate: chip0+chip1 together, chip2+chip3 together
    assert hosts[0] == hosts[1] and hosts[2] == hosts[3]


def test_explicit_placement_and_round_robin():
    ici = LinkSpec(bandwidth_bps=50e9 * 8, latency_ns=1_000)
    explicit = {f"chip{c}": c % 2 for c in range(4)}
    sim = Simulation(Topology.full_mesh(2, ici, n_cpus=4), small_train(),
                     placement=explicit).build()
    assert sim.placement == explicit
    sim2 = Simulation(Topology.full_mesh(2, ici, n_cpus=4), small_train(),
                      placement="round_robin").build()
    assert [sim2.placement[f"chip{c}"] for c in range(4)] == [0, 1, 0, 1]


def test_report_to_json_roundtrip():
    report = Simulation(Topology.single_host(n_cpus=4),
                        small_train()).run()
    d = json.loads(report.to_json())
    assert d["status"] == "ok"
    assert d["tasks"]["chip0"]["state"] == "done"
    assert d["progress"]["train"]["done_steps"] == [3, 3, 3, 3]
    assert isinstance(d["hosts"][0]["dispatches"], int)


def test_injection_unknown_target_rejected():
    with pytest.raises(ValueError):
        Simulation(Topology.single_host(), small_train(),
                   Scenario("bad", (Straggler("nope", 2.0),))).build()


def test_straggler_slows_only_target():
    base = Simulation(Topology.single_host(n_cpus=4), small_train()).run()
    slow = Simulation(
        Topology.single_host(n_cpus=4), small_train(),
        Scenario("straggler", (Straggler("chip1", 3.0),))).run()
    assert slow.tasks["chip1"]["vtime"] > base.tasks["chip1"]["vtime"]
    # ring coupling drags everyone, so total horizon also inflates
    assert slow.vtime_ns > base.vtime_ns


# -- scenario 1: straggler + mid-run host failure -----------------------------


def test_scenario_straggler_plus_host_failure_blast_radius():
    """A rack straggler plus a host dying mid-run: the ring partner
    wedges, and the facade reports the blast radius as structured data
    instead of crashing."""
    wl = RackRing(n_iters=100, skew_bound_ns=2_000_000)
    report = Simulation(
        Topology.racks(2, 2), wl,
        Scenario("straggler+host-death",
                 (Straggler("w1", 2.0), FailHost(host=3, at_vtime=200_000))),
        placement=wl.default_placement(), mode="async").run()
    assert report.status == "deadlock"
    done = np.array(report.progress["rack"]["iters_done"])
    assert report.tasks["w3"]["state"] == "done"   # died (body closed)
    assert done[3] < 100                           # short of the full run
    assert done.max() < 100       # ring coupling stalls the survivors too
    assert done.min() >= 1        # but everyone made some progress first
    # the report is still fully serializable mid-wreck
    json.loads(report.to_json())


def test_fail_task_at_vtime_single_host():
    report = Simulation(
        Topology.single_host(n_cpus=4), small_train(),
        Scenario("die", (FailTask("chip2", at_vtime=60_000),))).run()
    assert report.status == "deadlock"
    assert report.progress["train"]["done_steps"][2] < 3


# -- scenario 2: degraded cross-rack link -------------------------------------


def test_scenario_degraded_cross_rack_link():
    def run(scenario):
        wl = RackRing(n_iters=60, skew_bound_ns=2_000_000)
        return Simulation(Topology.racks(2, 2), wl, scenario,
                          placement=wl.default_placement(),
                          mode="async").run()

    base = run(Scenario())
    degraded = run(Scenario(
        "slow x-rack", (DegradeLink(hosts=(0, 2), latency_factor=8.0),)))
    assert base.status == "ok" and degraded.status == "ok"
    # leaders ride the degraded link; the whole ring finishes later
    assert degraded.vtime_ns > base.vtime_ns
    assert degraded.messages == base.messages


def test_degrade_from_vtime_only_affects_tail():
    def run(from_vtime):
        wl = RackRing(n_iters=60, skew_bound_ns=2_000_000)
        return Simulation(
            Topology.racks(2, 2), wl,
            Scenario("late", (DegradeLink(hosts=(0, 2), latency_factor=8.0,
                                          from_vtime=from_vtime),)),
            placement=wl.default_placement(), mode="async").run()

    early, late = run(0), run(10**12)
    assert early.vtime_ns > late.vtime_ns   # late start = no effect at all


def test_degrade_fabric_single_host():
    base = Simulation(Topology.single_host(n_cpus=4), small_train()).run()
    deg = Simulation(
        Topology.single_host(n_cpus=4), small_train(),
        Scenario("slow ici",
                 (DegradeLink(fabric="ici0", extra_ns=500_000),))).run()
    assert deg.vtime_ns > base.vtime_ns
    assert deg.messages == base.messages


# -- scenario 3: interference-coupled co-located serving + training -----------


def test_scenario_interference_colocated_serve_train():
    def run(workloads):
        return Simulation(Topology.single_host(n_cpus=1), workloads,
                          cpu_resource=True).run()

    train_alone = run([small_train()])
    serve_alone = run([ModeledServe(n_clients=2, n_requests=30)])
    both = run([small_train(), ModeledServe(n_clients=2, n_requests=30)])
    assert both.status == "ok"
    # both workloads completed under contention...
    assert both.progress["train"]["done_steps"] == [3, 3, 3, 3]
    assert both.progress["serve"]["served"] == [30, 30]
    # ...and each is measurably slower than when run in isolation
    assert (both.tasks["chip0"]["vtime"]
            > train_alone.tasks["chip0"]["vtime"])
    assert (both.tasks["serve.client0"]["vtime"]
            > serve_alone.tasks["serve.client0"]["vtime"])


def test_interference_injection_load_couples_timing():
    base = Simulation(Topology.single_host(n_cpus=1), small_train(),
                      cpu_resource=True).run()
    loaded = Simulation(
        Topology.single_host(n_cpus=1), small_train(),
        Scenario("noisy neighbor",
                 (Interference(co_locate_with="chip0", bursts=50,
                               burst_ns=20_000),)),
        cpu_resource=True).run()
    assert loaded.status == "ok"
    assert loaded.progress["train"]["done_steps"] == [3, 3, 3, 3]
    assert loaded.tasks["chip0"]["vtime"] > base.tasks["chip0"]["vtime"]


# -- multi-workload + misc ----------------------------------------------------


def test_duplicate_program_names_rejected():
    with pytest.raises(ValueError):
        Simulation(Topology.single_host(),
                   [small_train(), small_train()]).build()


def test_serve_workload_standalone():
    report = Simulation(Topology.single_host(n_cpus=4),
                        ModeledServe(n_clients=3, n_requests=20)).run()
    assert report.status == "ok"
    assert report.progress["serve"]["served"] == [20, 20, 20]
    assert report.messages == 2 * 3 * 20     # req + resp per request
