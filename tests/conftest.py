import pytest

from engine_harness import assert_engines_agree


@pytest.fixture
def engine_harness():
    """Cross-engine equivalence harness (see tests/engine_harness.py):
    call with a fresh-Simulation factory; it runs every applicable
    engine (single / barrier / async / dist with 1 and K workers) and
    asserts bit-identical results, returning the per-engine reports."""
    return assert_engines_agree
