"""LiveStack scheduler semantics (paper §3.2)."""
import pytest

from repro.core import (Compute, DeadlockError, Endpoint, Event, Hub,
                        LinkSpec, LiveCall, Recv, Scheduler, Scope, Send,
                        State, US, MS, VTask, Yield, Await)


def make_compute_task(name, n_steps, step_ns, scope=None):
    def body():
        for _ in range(n_steps):
            yield Compute(step_ns)

    t = VTask(name, body(), kind="modeled")
    if scope is not None:
        t.join(scope)
    return t


def test_bounded_skew_invariant():
    """No vtask may start a quantum more than skew ahead of scope min."""
    skew = 10 * US
    sc = Scope("s", skew)
    sched = Scheduler(n_cpus=1)
    fast = sched.spawn(make_compute_task("fast", 100, 5 * US, sc))
    slow = sched.spawn(make_compute_task("slow", 100, 50 * US, sc))

    violations = []
    orig = sched._dispatch

    def checked(t):
        sv = sc.vtime
        if sv >= 0 and t.vtime > sv + skew:
            violations.append((t.name, t.vtime, sv))
        orig(t)

    sched._dispatch = checked
    sched.run()
    assert not violations
    assert fast.state == State.DONE and slow.state == State.DONE
    # fast must have been stalled repeatedly waiting for slow
    assert sched.stats.skew_stalls > 0


def test_different_scopes_different_skew():
    tight = Scope("tight", 1 * US)
    loose = Scope("loose", 1 * MS)
    sched = Scheduler(n_cpus=1)
    a = sched.spawn(make_compute_task("a", 50, 2 * US, tight))
    b = sched.spawn(make_compute_task("b", 50, 2 * US, tight))
    c = sched.spawn(make_compute_task("c", 5, 100 * US, loose))
    a.join(loose)
    sched.run()
    assert all(t.state == State.DONE for t in (a, b, c))


def test_multi_scope_must_hold_everywhere():
    """A vtask in two scopes is gated by the tighter of the two."""
    s1 = Scope("s1", 5 * US)
    s2 = Scope("s2", 500 * US)
    sched = Scheduler(n_cpus=1)
    shared = sched.spawn(make_compute_task("shared", 200, 10 * US))
    shared.join(s1)
    shared.join(s2)
    anchor1 = sched.spawn(make_compute_task("anchor1", 10, 100 * US, s1))
    anchor2 = sched.spawn(make_compute_task("anchor2", 10, 100 * US, s2))
    violations = []
    orig = sched._dispatch

    def checked(t):
        if t is shared:
            for s in (s1, s2):
                if s.vtime >= 0 and t.vtime > s.vtime + s.skew_bound_ns:
                    violations.append(s.name)
        orig(t)

    sched._dispatch = checked
    sched.run()
    assert not violations


def test_blocked_excluded_from_scope_min():
    """Paper: a halted vCPU must not pin scope.vtime (VM-boot deadlock)."""
    sc = Scope("boot", 10 * US)
    sched = Scheduler(n_cpus=1)
    ev = Event()

    def sleeper():
        yield Await(ev)
        yield Compute(1 * US)

    def bootstrap():
        for _ in range(100):
            yield Compute(5 * US)
        ev.fire(500 * US)
        yield Compute(5 * US)

    s1 = sched.spawn(VTask("halted", sleeper(), kind="modeled"))
    s2 = sched.spawn(VTask("bootstrap", bootstrap(), kind="modeled"))
    s1.join(sc)
    s2.join(sc)
    sched.run()
    assert s1.state == State.DONE and s2.state == State.DONE
    # woken sleeper must have been forwarded, not dragged from vtime 0
    assert s1.vtime >= 500 * US


def test_wake_forwards_vtime():
    sc = Scope("s", 10 * US)
    sched = Scheduler(n_cpus=1)
    ev = Event()

    def sleeper():
        yield Await(ev)
        yield Compute(0)

    def runner():
        for i in range(10):
            yield Compute(100 * US)
        ev.fire(1 * MS)

    sl = sched.spawn(VTask("sleeper", sleeper(), kind="modeled"))
    rn = sched.spawn(VTask("runner", runner(), kind="modeled"))
    sl.join(sc)
    rn.join(sc)
    sched.run()
    # time causality: sleeper observed elapsed time on wake
    assert sl.vtime >= 1 * MS


def test_modeled_preemption_on_no_progress():
    """Faulty component reporting no progress must not stall the sim."""
    sc = Scope("s", 10 * US)
    sched = Scheduler(n_cpus=1, preempt_after=10)

    def faulty():
        while True:
            yield Compute(0)     # never reports progress

    f = sched.spawn(VTask("faulty", faulty(), kind="modeled"))
    g = sched.spawn(make_compute_task("good", 50, 5 * US))
    f.join(sc)
    g.join(sc)
    sched.run(max_rounds=100_000)
    assert f.state == State.FAULTY
    assert g.state == State.DONE
    assert sched.stats.preemptions == 1


def test_live_call_clock_derived_vtime():
    sched = Scheduler(n_cpus=1)
    acc = []

    def work():
        acc.append(sum(range(1000)))
        return acc[-1]

    def body():
        r = yield LiveCall(work)
        assert r == sum(range(1000))
        yield Compute(0)

    t = VTask("live", body(), kind="live")
    t.clock.calibration = 2.0
    sched.spawn(t)
    sched.run()
    assert t.state == State.DONE
    assert t.vtime > 0                       # measured, scaled
    assert t.stats["live_ns"] == t.vtime
    assert t.clock.total_vtime_ns == pytest.approx(
        2.0 * t.clock.total_host_ns, rel=0.01)


def test_live_call_cost_model_override():
    sched = Scheduler(n_cpus=1)

    def body():
        yield LiveCall(lambda: 42, cost_ns=123 * US)

    t = sched.spawn(VTask("live", body(), kind="live"))
    sched.run()
    assert t.vtime == 123 * US


def test_no_livelock_minimum_always_eligible():
    """The globally minimal runnable vtask is always eligible."""
    sc1, sc2 = Scope("a", 1 * US), Scope("b", 1 * US)
    sched = Scheduler(n_cpus=4)
    ts = []
    for i in range(6):
        t = sched.spawn(make_compute_task(f"t{i}", 30, (i + 1) * US))
        t.join(sc1 if i % 2 == 0 else sc2)
        if i % 3 == 0:
            t.join(sc2)
        ts.append(t)
    sched.run(max_rounds=100_000)
    assert all(t.state == State.DONE for t in ts)


def test_deadlock_detection():
    sched = Scheduler(n_cpus=1)
    ev = Event()   # never fired

    def waiter():
        yield Await(ev)

    sched.spawn(VTask("w", waiter(), kind="modeled"))
    with pytest.raises(DeadlockError):
        sched.run()


def test_figure2_timeline():
    """Reproduce the paper's Fig. 2: two live vCPUs + one modeled I/O
    device in one scope.  The device starts idle (blocked, excluded from
    the scope min); the vCPUs advance; the device wakes on an I/O request,
    is forwarded to the scope vtime, and its slow modeled progress then
    holds the vCPUs at the skew bound."""
    skew = 20 * US
    sc = Scope("fig2", skew)
    hub = Hub("h", LinkSpec(bandwidth_bps=80e9, latency_ns=1000))
    sched = Scheduler(n_cpus=2)

    dev_ep = hub.attach(Endpoint("dev"))
    cpu0_ep = hub.attach(Endpoint("cpu0"))

    def vcpu0():
        # compute, then issue I/O, then more compute
        for _ in range(5):
            yield Compute(10 * US)
        yield Send(cpu0_ep, "dev", 4096)
        for _ in range(20):
            yield Compute(10 * US)

    def vcpu1():
        for _ in range(25):
            yield Compute(10 * US)

    def device():
        msg = yield Recv(dev_ep)
        assert msg.size_bytes == 4096
        for _ in range(10):
            yield Compute(30 * US)       # slow modeled I/O processing

    t0 = sched.spawn(VTask("vcpu0", vcpu0(), kind="modeled"))
    t1 = sched.spawn(VTask("vcpu1", vcpu1(), kind="modeled"))
    td = VTask("dev", device(), kind="modeled")
    td.state = State.RUNNABLE
    sched.spawn(td)
    for t in (t0, t1, td):
        t.join(sc)

    sched.run()
    assert all(t.state == State.DONE for t in (t0, t1, td))
    # device woke at >= the I/O request time (forwarded, not from 0)
    assert td.vtime >= 50 * US
    # vCPUs were held at the skew bound while the device caught up
    assert sched.stats.skew_stalls > 0
    assert sched.stats.max_skew_seen <= skew


def test_determinism():
    def build():
        sc = Scope("s", 10 * US)
        hub = Hub("h")
        sched = Scheduler(n_cpus=3)
        eps = [hub.attach(Endpoint(f"e{i}")) for i in range(3)]

        def pingpong(i):
            def body():
                for r in range(10):
                    yield Compute((i + 1) * 3 * US)
                    yield Send(eps[i], f"e{(i + 1) % 3}", 100 * (r + 1))
                    msg = yield Recv(eps[i])
                    yield Compute(msg.size_bytes)
            return body

        ts = [sched.spawn(VTask(f"t{i}", pingpong(i)(), kind="modeled"))
              for i in range(3)]
        for t in ts:
            t.join(sc)
        sched.run()
        return [(t.name, t.vtime, t.stats["msgs_rx"]) for t in ts]

    assert build() == build()
