"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes + no NaNs, plus prefill/decode consistency
against the parallel forward pass (a strong end-to-end correctness check
for every cache implementation)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.compat import tree_leaves_with_path
from repro.models import registry
from repro.models.common import softmax_cross_entropy

ARCHS = configs.ARCHS


def _inputs(cfg, key, batch=2, seq=16):
    kt, kf = jax.random.split(key)
    tokens = jax.random.randint(kt, (batch, seq), 0, cfg.vocab)
    fe = None
    if cfg.frontend == "patch":
        nf = min(cfg.n_frontend_tokens, seq // 2)
        cfg = dataclasses.replace(cfg, n_frontend_tokens=nf)
        fe = jax.random.normal(kf, (batch, nf, cfg.frontend_dim),
                               jnp.float32)
    elif cfg.frontend == "audio":
        from repro.models import encdec

        fe = jax.random.normal(kf, (batch, encdec.enc_len(cfg, seq),
                                    cfg.frontend_dim), jnp.float32)
    return cfg, tokens, fe


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch, rng):
    cfg = configs.get_smoke(arch)
    cfg, tokens, fe = _inputs(cfg, rng)
    params = registry.init(cfg, rng)
    logits = registry.forward(cfg, params, tokens, frontend_embeds=fe)
    assert logits.shape == (*tokens.shape, cfg.vocab)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch, rng):
    cfg = configs.get_smoke(arch)
    cfg, tokens, fe = _inputs(cfg, rng)
    params = registry.init(cfg, rng)

    def loss_fn(p):
        logits = registry.forward(cfg, p, tokens, frontend_embeds=fe)
        return softmax_cross_entropy(logits[:, :-1], tokens[:, 1:])

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert bool(jnp.all(jnp.isfinite(g))), "non-finite grad"


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_match_init(arch, rng):
    cfg = configs.get_smoke(arch)
    params = registry.init(cfg, rng)
    specs = registry.param_specs(cfg)
    flat_p = tree_leaves_with_path(params)
    flat_s = tree_leaves_with_path(specs)
    assert len(flat_p) == len(flat_s)
    for (kp, vp), (ks, vs) in zip(flat_p, flat_s):
        assert kp == ks
        assert vp.shape == vs.shape, f"{kp}: {vp.shape} != {vs.shape}"
        assert vp.dtype == vs.dtype, f"{kp}: {vp.dtype} != {vs.dtype}"
    axes = registry.logical_axes(cfg)
    flat_a = tree_leaves_with_path(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_a) == len(flat_p)
    for (kp, vp), (ka, va) in zip(flat_p, flat_a):
        assert len(va) == vp.ndim, f"{kp}: axes {va} vs shape {vp.shape}"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch, rng):
    """decode_step after prefill must reproduce the parallel logits.

    Run in fp32: this is a math-equivalence test (cache plumbing, ring
    buffers, recurrent state), so dtype noise would only mask bugs."""
    cfg = dataclasses.replace(configs.get_smoke(arch), dtype=jnp.float32)
    if cfg.n_experts:
        # avoid capacity-drop nondeterminism between the two paths
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    seq = 12
    cfg, tokens, fe = _inputs(cfg, rng, batch=2, seq=seq + 1)
    params = registry.init(cfg, rng)

    logits_all = registry.forward(cfg, params, tokens, frontend_embeds=fe)
    logits_p, cache = registry.prefill(cfg, params, tokens[:, :seq],
                                       frontend_embeds=fe)
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(logits_all[:, seq - 1]),
        rtol=1e-4, atol=1e-4)
    logits_d, cache = registry.decode_step(cfg, params, tokens[:, seq],
                                           cache)
    np.testing.assert_allclose(
        np.asarray(logits_d), np.asarray(logits_all[:, seq]),
        rtol=1e-4, atol=1e-4)
