"""Versioned scenario registry: ref resolution, override rules, and
the pinned-golden check.

The golden check itself runs the cheap modeled refs here (CI runs the
full set via ``python -m repro.sim.registry check``; the gallery-tagged
entries are additionally byte-pinned by test_golden_trace.py, which now
*sources* its gallery from the registry).
"""
import pytest

from repro.sim import Scenario, Straggler, registry


def test_every_ref_loads_a_fresh_unbuilt_simulation():
    for ref in registry.names():
        sim = registry.load(ref)
        assert sim.topology.n_hosts >= 1
        assert registry.load(ref) is not sim


def test_bare_name_resolves_latest_version(monkeypatch):
    monkeypatch.setitem(registry._REGISTRY, "tmp_scn", {})
    registry.register("tmp_scn", 1, "v1", lambda s=None: None)
    registry.register("tmp_scn", 3, "v3", lambda s=None: None)
    registry.register("tmp_scn", 2, "v2", lambda s=None: None)
    assert registry.entry("tmp_scn").version == 3
    assert registry.entry("tmp_scn@v2").version == 2
    assert registry.entry("tmp_scn@v3").ref == "tmp_scn@v3"


def test_duplicate_registration_rejected(monkeypatch):
    monkeypatch.setitem(registry._REGISTRY, "tmp_dup", {})
    registry.register("tmp_dup", 1, "first", lambda s=None: None)
    with pytest.raises(ValueError, match="new version"):
        registry.register("tmp_dup", 1, "again", lambda s=None: None)


def test_unknown_refs_error_with_available_names():
    with pytest.raises(KeyError, match="registered:"):
        registry.entry("no_such_scenario")
    with pytest.raises(KeyError, match="no version v9"):
        registry.entry("serve_smoke@v9")
    with pytest.raises(KeyError, match="name@vN"):
        registry.entry("serve_smoke@latest")


def test_campaign_bases_accept_scenario_override():
    sc = Scenario("probe", (Straggler("serve.client0", 2.0),))
    sim = registry.load("serve_smoke@v1", scenario=sc)
    assert sim.scenario.name == "probe"
    assert registry.entry("serve_smoke@v1").grid().n_points == 16


def test_pinned_live_entries_reject_scenario_override():
    with pytest.raises(ValueError, match="pins its scenario"):
        registry.load("live_recovery@v1", scenario=Scenario("x"))


def test_campaign_derived_entry_reproduces_the_crash():
    # the checked-in minimized reproducer spec must still crash the
    # serve base the same way the campaign recorded
    rec = registry.golden_record("serve_flip_min@v1")
    assert rec["outcome"] == "crash"
    assert "unknown endpoint" in rec["detail"]


def test_golden_check_green_on_modeled_refs():
    cheap = ["rack_ring@v1", "serve_smoke@v1", "bitflip_serve@v1",
             "clock_skew_rack@v1", "serve_flip_min@v1"]
    assert registry.check(cheap) == []


def test_golden_check_flags_drift(tmp_path, monkeypatch):
    import json
    golden = json.loads(registry.GOLDEN.read_text())
    golden["rack_ring@v1"]["canonical"]["vtime_ns"] += 1
    fake = tmp_path / "registry.json"
    fake.write_text(json.dumps(golden))
    monkeypatch.setattr(registry, "GOLDEN", fake)
    failures = registry.check(["rack_ring@v1"])
    assert len(failures) == 1 and "rack_ring@v1" in failures[0]


def test_cli_list_json_is_machine_readable(capsys):
    assert registry.main(["list", "--json"]) == 0
    import json as _json
    rows = _json.loads(capsys.readouterr().out)
    by_ref = {r["ref"]: r for r in rows}
    assert set(by_ref) == set(registry.names())
    assert by_ref["rack_ring@v1"]["campaign_base"] is True
    assert by_ref["diurnal_autoscale@v1"]["tags"] == ["gallery",
                                                      "control"]
    assert by_ref["diurnal_autoscale@v1"]["version"] == 1


def test_cli_check_exits_nonzero_on_mismatch(tmp_path, monkeypatch,
                                             capsys):
    import json as _json
    golden = _json.loads(registry.GOLDEN.read_text())
    golden["rack_ring@v1"]["canonical"]["messages"] += 1
    fake = tmp_path / "registry.json"
    fake.write_text(_json.dumps(golden))
    monkeypatch.setattr(registry, "GOLDEN", fake)
    assert registry.main(["check", "rack_ring@v1"]) == 1
    assert "FAIL rack_ring@v1" in capsys.readouterr().out
    # and the clean pin is green through the same entry point
    monkeypatch.undo()
    assert registry.main(["check", "rack_ring@v1"]) == 0


def test_diurnal_autoscale_golden_pins_control_plane():
    import json as _json
    rec = _json.loads(registry.GOLDEN.read_text())["diurnal_autoscale@v1"]
    assert rec["outcome"] == "ok"
    sec = rec["canonical"]["control"]["autoserve"]
    moves = [(d["from"], d["to"]) for d in sec["decisions"]
             if d["from"] != d["to"]]
    # the marquee ramp: 4 -> 64 -> 4 over one diurnal period
    assert moves == [(4, 8), (8, 16), (16, 32), (32, 64),
                     (64, 32), (32, 16), (16, 8), (8, 4)]
    assert sec["peak_active"] == 64 and sec["final_active"] == 4
    joins = [e for e in rec["canonical"]["control"]["membership"]
             if e["event"] == "join"]
    assert len(joins) == 60
