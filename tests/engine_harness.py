"""Cross-engine equivalence harness.

One reusable correctness bar for every orchestration engine: given a
factory producing a *fresh* facade :class:`~repro.sim.Simulation`
(fresh because workloads carry mutable progress arrays), run it under
every applicable engine — ``single`` (1-host scheduler), ``barrier``,
``async``, and ``dist`` with both 1 and K OS worker processes — and
assert bit-identical simulation results:

* ``status`` (a wedged cluster must wedge identically),
* ``vtime_ns`` / per-task outcomes (final vtimes, states, hosts),
* message/byte totals and per-workload progress arrays,
* per-host §3.3 cell accounting (``SimReport.cells``: switches,
  reconditioning time, interference/self-pressure events, per-cell
  slowdown histograms — cell state is keyed by host, so every engine
  must charge the identical costs),
* per-link visibility-slack stats (multi-host engines, which share hub
  naming; the ``single`` engine materializes per-fabric hubs instead).

Engine-*dependent* counters (sync rounds, proxy syncs, wall clock) are
deliberately not compared — they are what the engines are allowed to
trade off.  Per-host dispatch *counts* fall in the same bucket: a
dispatch that finds a receive not yet ready blocks and retries, and how
many such retry dispatches happen is a property of the engine's window
schedule, not of the simulation — so ``hosts`` is excluded from the
bar for the reference engines and for the vectorized engine alike.

The vectorized engine (``engine="vectorized"``) joins through a
*two-tier* contract:

* **exact tier** (:func:`assert_vectorized_exact`) — auto-tick
  compiles; the full CORE_FIELDS bar plus per-link stats, bit-identical
  to any reference engine.
* **tolerance tier** (:func:`assert_vectorized_tolerance`) — explicit
  ``tick_ns`` quantization; schedule-independent invariants stay exact
  (status, per-task states/hosts, progress arrays, message/byte totals,
  per-link message/byte counts) while per-task vtimes and the horizon
  must sit within a pinned per-call bound.

Usage::

    def test_my_scenario(engine_harness):
        reports = engine_harness(lambda: Simulation(topo(), wl(), sc()))
        assert reports["async"].status == "ok"

or directly: ``assert_engines_agree(make_sim)``.
"""
from __future__ import annotations

import os
from typing import Callable, Dict, List, Optional

from repro.sim import Simulation, SimReport

#: fields every engine must agree on, bit-exactly
CORE_FIELDS = ("status", "n_hosts", "vtime_ns", "messages", "bytes",
               "tasks", "progress", "cells", "live")

HAS_FORK = hasattr(os, "fork")

#: default worker count for the multi-process engine ("K" in the issue)
DIST_WORKERS = 2


def engines_for(n_hosts: int, dist_workers: int = DIST_WORKERS
                ) -> List[str]:
    """All engines applicable to a topology.  ``dist:K`` means the
    multi-process engine with K OS workers (clamped to n_hosts by the
    coordinator, so 1-host topologies only get ``dist:1``)."""
    if n_hosts == 1:
        engines = ["single", "barrier", "async"]
        dist = ["dist:1"]
    else:
        engines = ["barrier", "async"]
        ks = sorted({1, min(dist_workers, n_hosts)})
        dist = [f"dist:{k}" for k in ks]
    return engines + (dist if HAS_FORK else [])


def run_engine(make_sim: Callable[[], Simulation], engine: str, *,
               worker_timeout: float = 60.0) -> SimReport:
    """Build a fresh Simulation and run it under ``engine``
    (``"single"``/``"barrier"``/``"async"``/``"vectorized"`` or
    ``"dist:K"``).  The vectorized engine always runs with
    ``verify=True`` (batched hub fan-out cross-checked against the
    round loop)."""
    sim = make_sim()
    if engine.startswith("dist"):
        k = int(engine.split(":")[1]) if ":" in engine else DIST_WORKERS
        return sim.run(engine="dist", n_workers=k,
                       worker_timeout=worker_timeout)
    if engine == "vectorized":
        return sim.run(engine="vectorized", verify=True)
    return sim.run(engine=engine)


def assert_reports_equal(a: SimReport, b: SimReport, *,
                         label: str = "") -> None:
    for field in CORE_FIELDS:
        av, bv = getattr(a, field), getattr(b, field)
        assert av == bv, (
            f"{label}: engines {a.mode}(x{a.n_workers}) vs "
            f"{b.mode}(x{b.n_workers}) disagree on {field}: "
            f"{av!r} != {bv!r}")
    if a.mode != "single" and b.mode != "single":
        # multi-host engines share hub naming; per-link accounting
        # (incl. min visibility slack) must replay identically across
        # process boundaries.
        assert a.links == b.links, (
            f"{label}: per-link stats diverge: {a.links} != {b.links}")


def assert_engines_agree(
        make_sim: Callable[[], Simulation], *,
        engines: Optional[List[str]] = None,
        dist_workers: int = DIST_WORKERS,
        worker_timeout: float = 60.0,
        label: str = "") -> Dict[str, SimReport]:
    """Run ``make_sim()`` under every engine and assert bit-identical
    results; returns the per-engine reports for further assertions."""
    if engines is None:
        engines = engines_for(make_sim().topology.n_hosts, dist_workers)
    assert engines, "no engines to compare"
    reports = {eng: run_engine(make_sim, eng,
                               worker_timeout=worker_timeout)
               for eng in engines}
    base = engines[0]
    for eng in engines[1:]:
        assert_reports_equal(reports[base], reports[eng],
                             label=label or base)
    return reports


def assert_vectorized_exact(
        make_sim: Callable[[], Simulation], *,
        ref_engine: str = "async",
        label: str = "") -> Dict[str, SimReport]:
    """Exact-tier bar: auto-tick vectorized run must be bit-identical
    to ``ref_engine`` on CORE_FIELDS (and per-link stats when the
    reference is hub-per-host, i.e. not ``single``)."""
    ref = run_engine(make_sim, ref_engine)
    vec = run_engine(make_sim, "vectorized")
    assert vec.tier == "exact", (
        f"{label}: expected the exact tier, compiled tier={vec.tier!r} "
        f"(tick_ns={vec.tick_ns})")
    assert_reports_equal(ref, vec, label=label or "vectorized")
    return {ref_engine: ref, "vectorized": vec}


def assert_vectorized_tolerance(
        make_sim: Callable[[], Simulation], tick_ns: int, *,
        vtime_tol_ns: int,
        ref_engine: str = "async",
        label: str = "") -> Dict[str, SimReport]:
    """Tolerance-tier bar for an explicit quantization tick: the
    schedule-independent invariants stay exact — status, per-task
    states and hosts, per-workload progress arrays, message/byte
    totals, per-link message/byte counts — while every per-task vtime
    and the horizon must lie within ``vtime_tol_ns`` of the reference.
    (Per-host dispatch counts are *not* an invariant — see the module
    docstring.)"""
    ref = run_engine(make_sim, ref_engine)
    vec = make_sim().run(engine="vectorized", tick_ns=tick_ns,
                         verify=True)
    lbl = label or "vectorized-tolerance"
    for field in ("status", "n_hosts", "messages", "bytes",
                  "progress", "cells"):
        av, bv = getattr(ref, field), getattr(vec, field)
        assert av == bv, (f"{lbl}: {field} not invariant under "
                          f"quantization: {av!r} != {bv!r}")
    assert set(ref.tasks) == set(vec.tasks), lbl
    for t, info in ref.tasks.items():
        v = vec.tasks[t]
        assert v["state"] == info["state"], (
            f"{lbl}: task {t} state {v['state']} != {info['state']}")
        assert v["host"] == info["host"], (
            f"{lbl}: task {t} host {v['host']} != {info['host']}")
        dv = abs(v["vtime"] - info["vtime"])
        assert dv <= vtime_tol_ns, (
            f"{lbl}: task {t} vtime off by {dv} ns "
            f"(> {vtime_tol_ns})")
    assert abs(ref.vtime_ns - vec.vtime_ns) <= vtime_tol_ns, (
        f"{lbl}: horizon off by {abs(ref.vtime_ns - vec.vtime_ns)} ns")
    if ref.mode != "single":
        assert set(ref.links) == set(vec.links), lbl
        for k, st in ref.links.items():
            assert vec.links[k]["messages"] == st["messages"], (
                f"{lbl}: link {k} message count diverged")
            assert vec.links[k]["bytes"] == st["bytes"], (
                f"{lbl}: link {k} byte count diverged")
    return {ref_engine: ref, "vectorized": vec}
