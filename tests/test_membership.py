"""Mutable cluster membership: hosts joining and leaving as
simulation events.

The engine-matrix tests drive the three churn shapes through every
engine (``barrier``/``async``/``dist:1``/``dist:K``) via the shared
harness — join mid-run, leave mid-run (FailHost as churn), and
join-then-leave on the same host — asserting bit-identical reports
*and* bit-identical ``SimReport.control`` membership timelines (the
harness CORE_FIELDS deliberately exclude ``control``, so the timeline
equality is asserted explicitly here).

Validation, the vectorized-engine guard, and the campaign fallback
routing for ``JoinHost`` grids are covered at the bottom.
"""
import pytest

from engine_harness import assert_engines_agree
from repro.sim import (Campaign, FaultGrid, JoinHost, RackRing,
                       Scenario, Simulation, Topology,
                       UnsupportedByEngine, registry)
from repro.sim.scenario import FailHost

_LINK = Topology(1).default_host_link


def _ring(n_hosts, scenario=None, n_iters=30, joins=()):
    topo = Topology.full_mesh(n_hosts, link=_LINK, n_cpus=2)
    for h, at in joins:
        topo.join(h, at)
    wl = RackRing(n_racks=1, hosts_per_rack=n_hosts, n_iters=n_iters,
                  compute_ns=5_000, msg_bytes=512)
    return Simulation(topo, wl, scenario or Scenario("membership"),
                      placement=wl.default_placement())


def _assert_control_agrees(reports):
    ref_eng = sorted(reports)[0]
    ref = reports[ref_eng]
    for eng, rep in reports.items():
        assert rep.control == ref.control, (
            f"control timeline diverged: {eng} vs {ref_eng}\n"
            f" got: {rep.control}\nwant: {ref.control}")
    return ref


def test_join_mid_run_engine_matrix():
    reports = assert_engines_agree(
        lambda: _ring(4, joins=((3, 400_000),)), label="join")
    r = _assert_control_agrees(reports)
    assert r.status == "ok"
    assert r.control["membership"] == [
        {"event": "join", "host": 3, "vtime": 400_000}]
    # the joiner's tasks spawned at the join time, not at 0
    assert all(v["vtime"] >= 400_000 for n, v in r.tasks.items()
               if v["host"] == 3)


def test_joinhost_injection_equals_topology_join():
    via_topo = _ring(4, joins=((3, 400_000),)).run(engine="async")
    via_inj = _ring(4, scenario=Scenario(
        "j", (JoinHost(3, 400_000),))).run(engine="async")
    assert via_inj.tasks == via_topo.tasks
    assert via_inj.control == via_topo.control
    assert via_inj.vtime_ns == via_topo.vtime_ns


def test_leave_mid_run_engine_matrix():
    # a dead ring partner wedges the survivor: every engine must agree
    # on the deadlock, the leave timeline, and the wedged-host detail
    reports = assert_engines_agree(
        lambda: _ring(2, scenario=Scenario(
            "leave", (FailHost(1, at_vtime=100_000),)), n_iters=50),
        label="leave")
    r = _assert_control_agrees(reports)
    assert r.status == "deadlock"
    assert r.control["membership"] == [
        {"event": "leave", "host": 1, "vtime": 100_000}]
    for eng, rep in reports.items():
        assert rep.detail_info.get("kind") == "wedged", (eng,
                                                         rep.detail_info)
        assert rep.detail_info.get("wedged_hosts") == [0], (eng,
                                                            rep.detail_info)


def test_join_then_leave_same_host_fresh_state():
    # host 3 joins at 200us and dies at 1ms: the timeline carries both
    # events in vtime order and the host does not resurrect (its tasks
    # end dead, never re-spawned)
    def make():
        return _ring(4, scenario=Scenario(
            "churn", (FailHost(3, at_vtime=1_000_000),)),
            n_iters=60, joins=((3, 200_000),))

    reports = assert_engines_agree(make, label="join-then-leave")
    r = _assert_control_agrees(reports)
    assert r.control["membership"] == [
        {"event": "join", "host": 3, "vtime": 200_000},
        {"event": "leave", "host": 3, "vtime": 1_000_000}]


def test_membership_epoch_counted_once_per_flip():
    sim = _ring(4, joins=((2, 300_000), (3, 300_000)))
    report = sim.run(engine="async")
    assert report.status == "ok"
    # both joiners share one vtime, so one epoch flip admits both
    assert sim.orchestrator.stats["membership_epochs"] == 1


def test_topology_join_validation():
    topo = Topology.full_mesh(4, link=_LINK, n_cpus=2)
    with pytest.raises(ValueError, match="outside"):
        topo.join(4, 1_000)
    with pytest.raises(ValueError, match="founding member"):
        topo.join(0, 1_000)
    with pytest.raises(ValueError, match=">= 1"):
        topo.join(3, 0)
    topo.join(3, 1_000)
    with pytest.raises(ValueError, match="already has a join event"):
        topo.join(3, 2_000)


def test_joinhost_injection_validation_at_build():
    with pytest.raises(ValueError, match="founding member"):
        _ring(4, scenario=Scenario("bad", (JoinHost(0, 1_000),))).build()
    # a JoinHost duplicating a Topology.join is a conflict, not a merge
    with pytest.raises(ValueError, match="already has a join event"):
        _ring(4, scenario=Scenario("dup", (JoinHost(3, 2_000),)),
              joins=((3, 1_000),)).build()


def test_capacity_pool_staggers_joins():
    topo = Topology.full_mesh(5, link=_LINK, n_cpus=2)
    topo.capacity_pool(range(2, 5), 1_000, stagger_ns=250)
    assert topo.joins == {2: 1_000, 3: 1_250, 4: 1_500}


def test_vectorized_engine_rejects_membership():
    with pytest.raises(UnsupportedByEngine, match="membership"):
        _ring(4, joins=((3, 400_000),)).run(engine="vectorized")
    with pytest.raises(UnsupportedByEngine, match="membership"):
        _ring(4, scenario=Scenario(
            "j", (JoinHost(3, 400_000),))).run(engine="vectorized")


def test_campaign_routes_join_host_to_fallback():
    # join_host points must leave the vectorized sweep fast path and
    # run per-point on the reference engine; sweepable kinds in the
    # same grid still take the fast path ("mixed")
    grid = FaultGrid(types=("join_host", "straggler"),
                     targets=("w3",), vtimes=(0, 20_000))
    camp = Campaign(lambda sc: registry.load("rack_ring@v1", sc), grid,
                    seed=3)
    rep = camp.run(minimize=False)
    assert rep.fast_path == "mixed"
    outcomes = {p["type"]: p["outcome"] for p in rep.points}
    assert set(outcomes) == {"join_host", "straggler"}
    # vtime 0 clamps to 1 (a vtime-0 join would be a founding member)
    p0 = next(p for p in rep.points
              if p["type"] == "join_host" and p["vtime"] == 0)
    assert p0["outcome"] in ("ok", "divergence")


def test_joinhost_spec_round_trip():
    from repro.sim.campaign import injection_from_dict, injection_to_dict
    d = injection_to_dict(JoinHost(3, 7))
    assert d == {"host": 3, "at_vtime": 7, "type": "JoinHost"}
    assert injection_from_dict(d) == JoinHost(3, 7)
